"""Substrate tests: optimizers, checkpoint store, data pipeline, FT,
compression — including the hypothesis property tests on system invariants."""

import os
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal images: deterministic fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint import CheckpointStore, latest_step, restore_pytree, \
    save_pytree
from repro.core.physical import compress_int8_ef, decompress_int8
from repro.data import DataConfig, SyntheticLMStream, batch_for_step
from repro.ft import ElasticPlanner
from repro.ft.elastic import stale_aggregate
from repro.optim import adamw, clip_by_global_norm, sgd, warmup_cosine

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(lr=0.1), lambda: sgd(lr=0.1, momentum=0.9),
    lambda: adamw(lr=0.05, weight_decay=0.0),
])
def test_optimizer_minimizes_quadratic(make_opt):
    opt = make_opt()
    target = jnp.asarray(RNG.normal(size=(8,)), jnp.float32)
    params = {"w": jnp.zeros(8, jnp.float32)}
    state = opt.init(params)
    for step in range(200):
        grads = {"w": params["w"] - target}
        params, state = opt.update(grads, state, params, jnp.int32(step))
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(target), atol=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((3,), -10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = sum(float(jnp.sum(jnp.square(l)))
                for l in jax.tree_util.tree_leaves(clipped))
    assert abs(total - 1.0) < 1e-5
    assert float(gn) > 1.0


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, warmup=10, total=100)
    vals = [float(lr(jnp.int32(s))) for s in range(100)]
    assert vals[0] < vals[9] <= 1e-3 + 1e-9
    assert vals[99] < vals[50] < vals[11]


# ---------------------------------------------------------------------------
# Checkpoint store
# ---------------------------------------------------------------------------


def _tree():
    return {
        "params": {"w": jnp.asarray(RNG.normal(size=(4, 3)), jnp.float32),
                   "b": jnp.asarray(RNG.normal(size=(3,)), jnp.bfloat16)},
        "step": jnp.int32(7),
    }


def test_checkpoint_roundtrip_identity(tmp_path):
    tree = _tree()
    save_pytree(str(tmp_path), 7, tree, extra={"data_step": 7})
    restored, step, extra = restore_pytree(str(tmp_path), like=tree)
    assert step == 7 and extra == {"data_step": 7}
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_latest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        store.save(s, tree)
    store.wait()
    assert latest_step(str(tmp_path)) == 4
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_checkpoint_crash_safety(tmp_path):
    """A torn write never corrupts LATEST (commit protocol)."""

    tree = _tree()
    save_pytree(str(tmp_path), 1, tree)
    # simulate a torn temp dir from a crash
    os.makedirs(tmp_path / ".tmp_ckpt_dead", exist_ok=True)
    with open(tmp_path / ".tmp_ckpt_dead" / "leaf_0.npy", "w") as f:
        f.write("garbage")
    restored, step, _ = restore_pytree(str(tmp_path), like=tree)
    assert step == 1


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_data_is_pure_function_of_step():
    dc = DataConfig(vocab=97, seq_len=16, global_batch=4, seed=3)
    a = batch_for_step(dc, 12)["tokens"]
    b = batch_for_step(dc, 12)["tokens"]
    c = batch_for_step(dc, 13)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert int(jnp.max(a)) < 97


def test_stream_resume_equals_uninterrupted():
    dc = DataConfig(vocab=50, seq_len=8, global_batch=2)
    full = [next(iter_) for iter_ in [SyntheticLMStream(dc)] for _ in range(6)]
    s1 = SyntheticLMStream(dc)
    first = [next(s1) for _ in range(3)]
    ckpt = s1.state_dict()
    s2 = SyntheticLMStream(dc)
    s2.load_state_dict(ckpt)
    rest = [next(s2) for _ in range(3)]
    for a, b in zip(first + rest, full):
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))


# ---------------------------------------------------------------------------
# Compression + bounded staleness (hypothesis properties)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(2, 10))
def test_error_feedback_total_is_preserved(seed, steps):
    """sum of dequantized transmissions + final residual == sum of inputs
    (error feedback never loses mass)."""

    rng = np.random.default_rng(seed)
    residual = jnp.zeros(32, jnp.float32)
    total_in = np.zeros(32, np.float64)
    total_tx = np.zeros(32, np.float64)
    for _ in range(steps):
        g = jnp.asarray(rng.normal(size=32) * rng.uniform(0.1, 10),
                        jnp.float32)
        q, scale, residual = compress_int8_ef(g, residual)
        total_in += np.asarray(g, np.float64)
        total_tx += np.asarray(decompress_int8(q, scale), np.float64)
    np.testing.assert_allclose(
        total_tx + np.asarray(residual, np.float64), total_in,
        rtol=1e-4, atol=1e-4,
    )


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 8))
def test_stale_aggregate_all_on_time_is_exact_sum(seed, n):
    rng = np.random.default_rng(seed)
    partials = jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)
    out, late = stale_aggregate(
        partials, jnp.ones(n, bool), jnp.zeros(5, jnp.float32)
    )
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(partials).sum(0), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(late), 0.0, atol=0)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_stale_aggregate_never_drops_mass(seed):
    """Over two steps, delayed contributions arrive exactly once."""

    rng = np.random.default_rng(seed)
    p1 = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
    arrived = jnp.asarray(rng.integers(0, 2, 4).astype(bool))
    out1, late = stale_aggregate(p1, arrived, jnp.zeros(3, jnp.float32))
    p2 = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
    out2, late2 = stale_aggregate(p2, jnp.ones(4, bool), late)
    np.testing.assert_allclose(
        np.asarray(out1 + out2),
        np.asarray(p1.sum(0) + p2.sum(0)), rtol=1e-4, atol=1e-5,
    )


# ---------------------------------------------------------------------------
# Elastic replanning
# ---------------------------------------------------------------------------


def test_elastic_replan_keeps_model_axis():
    ep = ElasticPlanner(model_axis=16)
    mesh, stranded = ep.replan(512, multi_pod=True)
    assert mesh.size("model") == 16 and mesh.n_devices == 512
    mesh, stranded = ep.replan(500)       # lost 12 devices
    assert mesh.size("model") == 16
    assert mesh.n_devices == 496 and stranded == 4
    with pytest.raises(RuntimeError):
        ep.replan(7)


def test_elastic_replan_is_deterministic():
    ep = ElasticPlanner(model_axis=16)
    assert ep.replan(300) == ep.replan(300)
