"""Chaos differential conformance on 8 virtual devices (subprocess).

Each workload in spmd_ft_program.py runs three ways on an 8-shard mesh:
uninterrupted, crash+restore-from-checkpoint, and device-kill followed by
an 8->4 remesh that resumes from the (host-side, unsharded) checkpoints.
Both fault paths must land on the uninterrupted answer to <= 1e-8, report
their restarts/remesh events, and record the new topology in plan notes.
"""

import pytest

from _spmd_subprocess import run_spmd_program

WORKLOADS = ("tc", "cc_semi_naive", "pipeline", "sssp_weighted")


@pytest.fixture(scope="module")
def results():
    return run_spmd_program("spmd_ft_program.py")


@pytest.mark.parametrize("name", WORKLOADS)
def test_crash_restore_matches_uninterrupted(results, name):
    out = results[name]
    assert out["crash_err"] <= 1e-8, out
    assert out["crash_restarts"] >= 1, out


@pytest.mark.parametrize("name", WORKLOADS)
def test_remesh_8_to_4_matches_uninterrupted(results, name):
    out = results[name]
    assert out["remesh_crash_raised"], out
    assert out["remesh_err"] <= 1e-8, out
    assert out["remesh_note"], out
    assert out["remesh_events"] == 1, out


@pytest.mark.parametrize("name", ("tc", "cc_semi_naive", "pipeline"))
def test_resumed_phase_cursor_matches_uninterrupted(results, name):
    out = results[name]
    assert out["phases_equal"], out
    assert out["remesh_phases_equal"], out
