"""End-to-end tests of the declarative stack: UDFs -> Datalog -> XY schedule
-> logical plan -> physical plan -> executed fixpoint, validated against
closed-form / numpy oracles (paper §5 tasks at unit scale)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import algebra
from repro.core.fixpoint import DriverConfig, HostFixpointDriver
from repro.core.imru import IMRUTask, compile_imru
from repro.core.pregel import Graph, VertexProgram, compile_pregel
from repro.checkpoint import CheckpointStore

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# BGD via IMRU (paper §5.1)
# ---------------------------------------------------------------------------


def _bgd_task(n, d, lr):
    X = RNG.normal(size=(n, d)).astype(np.float32)
    w_true = RNG.normal(size=(d,)).astype(np.float32)
    y = X @ w_true
    task = IMRUTask(
        init_model=lambda: jnp.zeros((d,), jnp.float32),
        map=lambda rec, m: ((rec["x"] @ m - rec["y"]) @ rec["x"]),
        update=lambda j, m, g: m - lr * g,
        tol=1e-7,
    )
    return task, {"x": jnp.asarray(X), "y": jnp.asarray(y)}, X, y, w_true


def _gd_oracle(X, y, lr, iters):
    w = np.zeros(X.shape[1], np.float64)
    for _ in range(iters):
        w = w - lr * (X.T @ (X @ w - y))
    return w


def test_bgd_matches_gd_oracle_exactly():
    task, records, X, y, w_true = _bgd_task(256, 6, 1e-4)
    ex = compile_imru(task, records)
    res = ex.run(max_iters=200)
    oracle = _gd_oracle(X.astype(np.float64), y.astype(np.float64),
                        1e-4, res.iterations)
    np.testing.assert_allclose(np.asarray(res.state), oracle, atol=1e-3)


def test_bgd_converges_to_true_model():
    task, records, X, y, w_true = _bgd_task(512, 8, 2e-5)
    ex = compile_imru(task, records)
    res = ex.run(max_iters=5000)
    assert res.converged
    np.testing.assert_allclose(np.asarray(res.state), w_true, atol=1e-3)


def test_imru_pipeline_is_wired_through_datalog():
    task, records, *_ = _bgd_task(64, 4, 1e-4)
    ex = compile_imru(task, records)
    # the Datalog program validated + translated (Fig. 2 structure)
    assert ex.program.name == "imru"
    body_targets = [df.target for df in ex.logical.body]
    assert body_targets == ["collect", "model"]
    # physical planner rules fired
    assert any("loop-invariant-caching" in n for n in ex.plan.notes)
    assert any("early-aggregation" in n for n in ex.plan.notes)
    assert any("aggregation-tree" in n for n in ex.plan.notes)


def test_imru_microbatching_matches_unbatched():
    task, records, *_ = _bgd_task(256, 4, 1e-4)
    ex1 = compile_imru(task, records, microbatches=1)
    ex4 = compile_imru(task, records, microbatches=4)
    r1 = ex1.run(max_iters=50)
    r4 = ex4.run(max_iters=50)
    np.testing.assert_allclose(
        np.asarray(r1.state), np.asarray(r4.state), rtol=1e-5
    )


def test_imru_host_driver_checkpoint_restart(tmp_path):
    """Injected failure mid-run -> restore from checkpoint -> same fixpoint."""

    task, records, X, y, _ = _bgd_task(128, 4, 1e-4)
    ex = compile_imru(task, records)
    store = CheckpointStore(str(tmp_path), keep=2)

    def save(state, j):
        store.save(j, state)
        store.wait()

    def restore():
        state, j, _ = store.restore(like=ex.init())
        return state, j

    driver = ex.driver(
        DriverConfig(max_iters=60, checkpoint_every=10),
        save=save, restore=restore,
    )
    driver.fail_at = 25
    res = driver.run(ex.init())
    assert driver.restarts == 1
    clean = ex.run(max_iters=60, on_device=False)
    np.testing.assert_allclose(
        np.asarray(res.state), np.asarray(clean.state), rtol=1e-5
    )


def test_straggler_detection_logs_event():
    import time

    calls = {"n": 0}

    def slow_step(state, j):
        calls["n"] += 1
        if j == 8:
            time.sleep(0.3)
        return state + 0.0

    driver = HostFixpointDriver(
        step=slow_step,
        converged=lambda a, b: False,
        config=DriverConfig(max_iters=12, straggler_factor=3.0),
    )
    driver.run(jnp.zeros(4))
    assert driver.straggler_events >= 1


# ---------------------------------------------------------------------------
# PageRank via Pregel (paper §5.2)
# ---------------------------------------------------------------------------


def _random_graph(N, seed=1):
    rng = np.random.default_rng(seed)
    src, dst = [], []
    for v in range(N):
        for _ in range(rng.integers(1, 5)):
            src.append(v)
            dst.append(int(rng.integers(0, N)))
    for v in range(N):  # every vertex receives >= 1 edge
        src.append(int(rng.integers(0, N)))
        dst.append(v)
    return np.array(src, np.int32), np.array(dst, np.int32)


def _pagerank_oracle(src, dst, N, iters):
    outdeg = np.bincount(src, minlength=N).astype(np.float64)
    P = np.zeros((N, N))
    for s, d in zip(src, dst):
        P[d, s] += 1.0 / outdeg[s]
    r = np.full(N, 1.0 / N)
    for _ in range(iters):
        r = 0.15 / N + 0.85 * P @ r
    return r


def _pagerank_prog(N, outdeg):
    od = jnp.asarray(outdeg)
    return VertexProgram(
        init_vertex=lambda ids, vd: jnp.stack(
            [jnp.full((N,), 1.0 / N), od], axis=1
        ),
        message=lambda j, s, ed: s[:, 0] / jnp.maximum(s[:, 1], 1.0),
        apply=lambda j, s, inbox, got: (
            jnp.stack([0.15 / N + 0.85 * inbox, s[:, 1]], axis=1),
            jnp.ones(s.shape[0], jnp.bool_),
        ),
        combine="sum",
    )


@pytest.mark.parametrize("connector", ["dense_psum", "merging", "hash_sort"])
def test_pagerank_matches_oracle(connector):
    N = 64
    src, dst = _random_graph(N)
    outdeg = np.bincount(src, minlength=N).astype(np.float32)
    g = Graph(N, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(outdeg))
    ex = compile_pregel(_pagerank_prog(N, outdeg), g,
                        force_connector=connector)
    res = ex.run(max_iters=30)
    oracle = _pagerank_oracle(src, dst, N, 30)
    np.testing.assert_allclose(
        np.asarray(res.state[0][:, 0]), oracle, atol=1e-6
    )


def test_pregel_pipeline_is_wired_through_datalog():
    N = 16
    src, dst = _random_graph(N)
    outdeg = np.bincount(src, minlength=N).astype(np.float32)
    g = Graph(N, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(outdeg))
    ex = compile_pregel(_pagerank_prog(N, outdeg), g)
    assert ex.program.name == "pregel"
    # Fig. 3 firing order: collect before superstep before vertex/send
    targets = [df.target for df in ex.logical.body]
    assert targets.index("collect") < targets.index("superstep")
    assert targets.index("superstep") < targets.index("vertex")
    assert any("early-grouping" in n for n in ex.plan.notes)
    assert any("storage-selection" in n for n in ex.plan.notes)


def test_pregel_vote_to_halt_terminates_on_monotone_task():
    """Connected components by max-propagation: monotone, so vote-to-halt
    provably quiesces (the classic Pregel termination example) — and the
    fixpoint matches a union-find oracle."""

    N = 32
    rng = np.random.default_rng(3)
    # two disconnected rings + random intra-component chords
    comp = [list(range(0, N // 2)), list(range(N // 2, N))]
    src, dst = [], []
    for nodes in comp:
        for i, v in enumerate(nodes):
            w = nodes[(i + 1) % len(nodes)]
            src += [v, w]
            dst += [w, v]
        for _ in range(8):
            a, b = rng.choice(nodes, 2)
            src += [int(a), int(b)]
            dst += [int(b), int(a)]
    src = np.array(src, np.int32)
    dst = np.array(dst, np.int32)

    prog = VertexProgram(
        init_vertex=lambda ids, vd: ids.astype(jnp.float32),
        message=lambda j, s, ed: s,          # s is already per-edge src state
        apply=lambda j, s, inbox, got: (
            jnp.maximum(s, inbox), jnp.maximum(s, inbox) > s,
        ),
        combine="max",
    )
    g = Graph(N, jnp.asarray(src), jnp.asarray(dst),
              jnp.zeros(N, jnp.float32))
    ex = compile_pregel(prog, g)
    res = ex.run(max_iters=200)
    assert res.converged
    assert res.iterations < 200
    labels = np.asarray(res.state[0])
    assert np.all(labels[: N // 2] == N // 2 - 1)
    assert np.all(labels[N // 2:] == N - 1)
