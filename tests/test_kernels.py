"""Pallas kernel validation: interpret-mode sweeps vs pure-jnp oracles."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal images: deterministic fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.segment_combine.ops import segment_combine
from repro.kernels.segment_combine.ref import segment_combine_reference

RNG = np.random.default_rng(0)


def _mk(B, H, KH, Sq, Skv, D, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, Sq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, KH, Skv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, KH, Skv, D)), dtype)
    return q, k, v


FLASH_SWEEP = [
    # B, H, KH, Sq, Skv, D, causal, window, dtype, tol
    (1, 2, 2, 128, 128, 64, True, None, jnp.float32, 2e-6),
    (2, 4, 2, 128, 128, 64, True, None, jnp.float32, 2e-6),   # GQA
    (1, 4, 1, 64, 64, 32, False, None, jnp.float32, 2e-6),    # MQA bidir
    (1, 2, 2, 128, 128, 64, True, 64, jnp.float32, 2e-6),     # SWA
    (1, 2, 2, 256, 256, 64, True, 32, jnp.float32, 2e-6),     # narrow SWA
    (1, 2, 1, 64, 256, 64, True, None, jnp.float32, 2e-6),    # Sq < Skv
    (1, 2, 2, 128, 128, 128, True, None, jnp.float32, 2e-6),  # D=128
    (1, 2, 2, 128, 128, 64, True, None, jnp.bfloat16, 3e-2),
    (1, 8, 2, 64, 64, 32, True, None, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize(
    "B,H,KH,Sq,Skv,D,causal,window,dtype,tol", FLASH_SWEEP
)
def test_flash_forward_matches_reference(B, H, KH, Sq, Skv, D, causal,
                                         window, dtype, tol):
    q, k, v = _mk(B, H, KH, Sq, Skv, D, dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True, block_q=64, block_k=64)
    ref = attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol
    )


@pytest.mark.parametrize(
    "B,H,KH,Sq,Skv,D,causal,window",
    [(1, 2, 2, 128, 128, 64, True, None),
     (1, 4, 2, 128, 128, 64, True, None),
     (1, 2, 2, 128, 128, 64, True, 64),
     (2, 2, 1, 64, 64, 32, False, None)],
)
def test_flash_backward_matches_reference(B, H, KH, Sq, Skv, D, causal,
                                          window):
    q, k, v = _mk(B, H, KH, Sq, Skv, D, jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(
            q, k, v, causal=causal, window=window, interpret=True,
            block_q=64, block_k=64,
        ) * jnp.cos(jnp.arange(D, dtype=jnp.float32)))

    def f_ref(q, k, v):
        return jnp.sum(attention_reference(
            q, k, v, causal=causal, window=window,
        ) * jnp.cos(jnp.arange(D, dtype=jnp.float32)))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_flash_fully_masked_rows_are_zero():
    # window=1 + Sq==Skv: row 0 sees only itself; bidirectional masked case
    q, k, v = _mk(1, 2, 2, 64, 64, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=1, interpret=True,
                          block_q=32, block_k=32)
    ref = attention_reference(q, k, v, causal=True, window=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


SEG_SWEEP = [
    (1000, 8, 64, "sum"), (513, 16, 200, "sum"), (2048, 32, 256, "sum"),
    (256, 4, 32, "max"), (777, 8, 130, "min"), (64, 128, 16, "sum"),
]


@pytest.mark.parametrize("E,F,N,op", SEG_SWEEP)
def test_segment_combine_matches_reference(E, F, N, op):
    ids = np.sort(RNG.integers(0, N, size=E - 3)).astype(np.int32)
    ids = np.concatenate([ids, np.full(3, -1, np.int32)])  # padding rows
    vals = RNG.normal(size=(E, F)).astype(np.float32)
    out = segment_combine(jnp.asarray(vals), jnp.asarray(ids), N, op,
                          interpret=True)
    ref = segment_combine_reference(jnp.asarray(vals), jnp.asarray(ids), N, op)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_kernel_eligible_accepts_bf16_rejects_wider():
    # bf16 payloads must ride the Pallas kernel (f32 accumulation, cast
    # back on output) instead of silently skipping to the XLA fallback;
    # f64/int payloads would be narrowed by the f32 accumulator and stay
    # ineligible.
    from repro.kernels.segment_combine.ops import kernel_eligible

    bf16 = jnp.zeros((8, 2), jnp.bfloat16)
    f32 = jnp.zeros((8, 2), jnp.float32)
    i32 = jnp.zeros((8, 2), jnp.int32)
    assert kernel_eligible(bf16, True)
    assert kernel_eligible(f32, True)
    assert not kernel_eligible(i32, True)
    if jax.default_backend() != "tpu":
        # off-TPU without interpret mode there is no kernel to run at all
        assert not kernel_eligible(bf16, None)


@pytest.mark.parametrize("op", ["sum", "max", "min"])
def test_segment_combine_bf16_payload_matches_f32_reference(op):
    # The kernel accumulates bf16 payloads in f32 and casts back, so the
    # result must agree with the f32 reference to bf16 resolution.
    E, F, N = 600, 4, 40
    rng = np.random.default_rng(9)
    ids = jnp.asarray(np.sort(rng.integers(0, N, E)).astype(np.int32))
    vals32 = rng.normal(size=(E, F)).astype(np.float32)
    out = segment_combine(jnp.asarray(vals32, jnp.bfloat16), ids, N, op,
                          interpret=True)
    assert out.dtype == jnp.bfloat16
    ref = segment_combine_reference(
        jnp.asarray(vals32, jnp.bfloat16).astype(jnp.float32), ids, N, op)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)


@settings(max_examples=25, deadline=None)
@given(
    n_seg=st.integers(2, 40),
    n_rows=st.integers(1, 200),
    f=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_segment_combine_property_sorted_sum(n_seg, n_rows, f, seed):
    """Kernel == oracle == dense matmul for any sorted id multiset."""

    rng = np.random.default_rng(seed)
    ids = np.sort(rng.integers(0, n_seg, size=n_rows)).astype(np.int32)
    vals = rng.normal(size=(n_rows, f)).astype(np.float32)
    out = segment_combine(jnp.asarray(vals), jnp.asarray(ids), n_seg, "sum",
                          interpret=True)
    dense = np.zeros((n_seg, f), np.float32)
    for i, s in enumerate(ids):
        dense[s] += vals[i]
    np.testing.assert_allclose(np.asarray(out), dense, atol=1e-4)
