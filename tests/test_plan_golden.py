"""Golden ``plan.notes`` snapshots for the cost-based planner.

The applied-rule notes are the planner's public record of which rewrites
fired (tests, EXPERIMENTS.md and the benchmarks all key off them), so a
planner change that silently adds, drops, or reorders a rewrite decision on
any mesh shape must show up as a diff here.  Snapshots cover 1/2/4-way data
meshes plus a 2x2 pod mesh for both ``plan_pregel`` and ``plan_imru``,
unweighted and weighted (``edge_attr_bytes > 0``) graph statistics.
"""

import dataclasses

import numpy as np

from repro.core.hardware import MeshSpec
from repro.core.planner import IMRUStats, PregelStats, plan_imru, plan_pregel

MESHES = {
    "1way": MeshSpec((("data", 1),)),
    "2way": MeshSpec((("data", 2),)),
    "4way": MeshSpec((("data", 4),)),
    "2x2pod": MeshSpec((("pod", 2), ("data", 2))),
}

PREGEL_STATS = PregelStats(n_vertices=4096, n_edges=65536,
                           vertex_bytes=4, msg_bytes=4)
IMRU_STATS = IMRUStats(n_records=1_000_000, record_bytes=400,
                       model_bytes=16 * 2**20, stat_bytes=16 * 2**20,
                       flops_per_record=1e4)

_PREGEL_BASE = (
    "storage-selection(dense-indexed-state)",
    "join-algorithm(index-gather)",
    "loop-invariant-caching(graph)",
    "early-grouping(sender-combine)",
    "connector(dense_psum)",
)

PREGEL_GOLDEN = {
    # Single shard: no interconnect, the sparse path wins below 50% density.
    ("1way", True): _PREGEL_BASE + (
        "semi-naive(adaptive dense<->sparse @ density 0.5)",
    ),
    # Sharded: the per-shard compaction + frontier-sized bucket-a2a plan is
    # recorded, but on this tiny (65K-edge) graph the alpha terms of the
    # sparse exchange never beat one dense psum on the TPU hardware model —
    # the threshold solves to the "sparse never wins" sentinel.
    ("2way", True): _PREGEL_BASE + (
        "sharded-delta(per-shard compaction, bucket-a2a x2, "
        "collective mode-agreement)",
        "semi-naive(adaptive dense<->sparse @ density 0)",
    ),
    ("4way", True): _PREGEL_BASE + (
        "sharded-delta(per-shard compaction, bucket-a2a x4, "
        "collective mode-agreement)",
        "semi-naive(adaptive dense<->sparse @ density 0)",
    ),
    ("2x2pod", True): _PREGEL_BASE + (
        "sharded-delta(per-shard compaction, bucket-a2a x4, "
        "collective mode-agreement)",
        "semi-naive(adaptive dense<->sparse @ density 0)",
    ),
    ("1way", False): _PREGEL_BASE,
    ("2way", False): _PREGEL_BASE,
    ("4way", False): _PREGEL_BASE,
    ("2x2pod", False): _PREGEL_BASE,
}

_IMRU_BASE = (
    "loop-invariant-caching(training_data)",
    "early-aggregation(map-local)",
    "model-volume(replicate-params)",
)

IMRU_GOLDEN = {
    "1way": _IMRU_BASE + ("aggregation-tree(flat)",),
    "2way": _IMRU_BASE + ("aggregation-tree(flat)",),
    "4way": _IMRU_BASE + ("aggregation-tree(flat)",),
    # Multi-pod: the 16 MB gradient crosses DCN — ZeRO-1 reduce-scatter wins.
    "2x2pod": _IMRU_BASE + ("aggregation-tree(scatter)",),
}


WEIGHTED_STATS = dataclasses.replace(PREGEL_STATS, edge_attr_bytes=4)

# Weighted graphs: the edge-payload note lands right after the connector
# choice, before the semi-naive policy notes.
PREGEL_WEIGHTED_GOLDEN = {
    ("1way", True): _PREGEL_BASE + (
        "edge-payload(4B/edge)",
        "semi-naive(adaptive dense<->sparse @ density 0.5)",
    ),
    ("4way", True): _PREGEL_BASE + (
        "edge-payload(4B/edge)",
        "sharded-delta(per-shard compaction, bucket-a2a x4, "
        "collective mode-agreement)",
        "semi-naive(adaptive dense<->sparse @ density 0)",
    ),
    ("1way", False): _PREGEL_BASE + ("edge-payload(4B/edge)",),
    ("4way", False): _PREGEL_BASE + ("edge-payload(4B/edge)",),
}


def test_pregel_plan_notes_golden():
    for (mesh_name, semi_naive), want in PREGEL_GOLDEN.items():
        plan = plan_pregel(PREGEL_STATS, MESHES[mesh_name],
                           semi_naive=semi_naive)
        assert plan.notes == want, (mesh_name, semi_naive, plan.notes)


def test_pregel_weighted_plan_notes_golden():
    for (mesh_name, semi_naive), want in PREGEL_WEIGHTED_GOLDEN.items():
        plan = plan_pregel(WEIGHTED_STATS, MESHES[mesh_name],
                           semi_naive=semi_naive)
        assert plan.notes == want, (mesh_name, semi_naive, plan.notes)


def test_imru_plan_notes_golden():
    for mesh_name, want in IMRU_GOLDEN.items():
        plan = plan_imru(IMRU_STATS, MESHES[mesh_name])
        assert plan.notes == want, (mesh_name, plan.notes)


def test_pregel_sharded_threshold_nonzero_at_scale():
    """On a production-sized graph the frontier-sized bucket exchange DOES
    beat the dense psum below a density threshold that shrinks as the dense
    exchange amortizes over more shards — pin the ladder's solutions so the
    cost model can't drift silently."""

    stats = PregelStats(n_vertices=10_000_000, n_edges=500_000_000,
                        vertex_bytes=8, msg_bytes=8)
    thresholds = {
        dp: plan_pregel(stats, MeshSpec((("data", dp),)),
                        semi_naive=True).density_threshold
        for dp in (2, 8, 16)
    }
    assert thresholds == {2: 0.0625, 8: 0.0078125, 16: 0.00390625}


def test_pregel_weighted_payload_shifts_threshold_ladder():
    """Per-edge attribute bytes widen the edge pipeline the dense path pays
    at full E, so compaction wins earlier: the weighted ladder crosses at a
    density >= the unweighted one, strictly higher where the power-of-two
    ladder resolves the difference (dp=8 for the reference stats)."""

    stats = PregelStats(n_vertices=10_000_000, n_edges=500_000_000,
                        vertex_bytes=8, msg_bytes=8, edge_attr_bytes=8)
    thresholds = {
        dp: plan_pregel(stats, MeshSpec((("data", dp),)),
                        semi_naive=True).density_threshold
        for dp in (2, 8, 16)
    }
    assert thresholds == {2: 0.0625, 8: 0.015625, 16: 0.00390625}


MONOID_STATS = dataclasses.replace(PREGEL_STATS, msg_bytes=8,
                                   combine="argmin")

# Generic-monoid aggregates (no psum-scatter fast path): the dense
# connector pays an all-gather, which flips the sharded choice to the
# sparse hash_sort plan; the monoid's payload-width term is recorded right
# after the connector note.
PREGEL_MONOID_GOLDEN = {
    ("1way", False): _PREGEL_BASE + (
        "combine-monoid(argmin, 8B/msg, xla-generic)",
    ),
    ("1way", True): _PREGEL_BASE + (
        "combine-monoid(argmin, 8B/msg, xla-generic)",
        "semi-naive(adaptive dense<->sparse @ density 0.5)",
    ),
    ("4way", False): _PREGEL_BASE[:-1] + (
        "connector(hash_sort)",
        "combine-monoid(argmin, 8B/msg, xla-generic)",
    ),
    ("4way", True): _PREGEL_BASE[:-1] + (
        "connector(hash_sort)",
        "combine-monoid(argmin, 8B/msg, xla-generic)",
        "sharded-delta(per-shard compaction, bucket-a2a x4, "
        "collective mode-agreement)",
        "semi-naive(adaptive dense<->sparse @ density 0)",
    ),
}


def test_pregel_monoid_plan_notes_golden():
    for (mesh_name, semi_naive), want in PREGEL_MONOID_GOLDEN.items():
        plan = plan_pregel(MONOID_STATS, MESHES[mesh_name],
                           semi_naive=semi_naive)
        assert plan.notes == want, (mesh_name, semi_naive, plan.notes)


def test_pregel_fast_path_monoid_keeps_psum_connector():
    # mean rides the sum fast path: no all-gather penalty, dense_psum
    # stays the 4-way winner, and the note records the ridden path.
    stats = dataclasses.replace(PREGEL_STATS, msg_bytes=8, combine="mean")
    plan = plan_pregel(stats, MESHES["4way"])
    assert plan.connector == "dense_psum"
    assert "combine-monoid(mean, 8B/msg, sum-fast-path)" in plan.notes


def test_pregel_sparse_cap_floor_scales_down_for_small_shards():
    """The planner-derived per-shard compaction capacity: capped at 64 for
    production slabs, but no more than a quarter of a small local slab so
    the sparse path can engage on test-sized graphs."""

    big = plan_pregel(PREGEL_STATS, MESHES["4way"], semi_naive=True)
    assert big.sparse_cap_floor == 64
    small = plan_pregel(
        PregelStats(n_vertices=64, n_edges=288, vertex_bytes=4, msg_bytes=4),
        MeshSpec((("data", 8),)), semi_naive=True,
    )
    assert small.sparse_cap_floor == 8
    assert small.sparse_cap_for(3) == 8
    assert small.sparse_cap_for(100) == 128


# ---------------------------------------------------------------------------
# Generic-program plans (the unified executor)
# ---------------------------------------------------------------------------
#
# The listing snapshots above double as the refactor guard: compile_pregel /
# compile_imru now lower through repro.core.executor, and their plan.notes
# must stay byte-identical (tests/test_executor.py additionally asserts the
# compile_program-dispatched plans carry the same notes).  The snapshots
# below pin the NEW generic-program plans: dense-grid storage, fixpoint
# phases, and the per-GroupBy Fig.-9 connector selection.

GENERIC_N = 64

GENERIC_GOLDEN = {
    ("transitive-closure", False): (
        "storage-selection(dense-grid[n=64])",
        "loop-invariant-caching(edb-grids)",
    ),
    ("connected-components", False): (
        "storage-selection(dense-grid[n=64])",
        "loop-invariant-caching(edb-grids)",
        "groupby(C2: min via dense-reduce, 4096 rows -> 64)",
    ),
    ("connected-components", True): (
        "storage-selection(dense-grid[n=64])",
        "loop-invariant-caching(edb-grids)",
        "groupby(C2: min via dense-reduce, 4096 rows -> 64)",
        "semi-naive(C2: cc -> Δcc)",
    ),
    ("same-generation", False): (
        "storage-selection(dense-grid[n=64])",
        "loop-invariant-caching(edb-grids)",
    ),
    ("pagerank-threshold", False): (
        "storage-selection(dense-grid[n=64])",
        "loop-invariant-caching(edb-grids)",
        "fixpoint-phases(rank -> reach)",
        "groupby(P2: sum via dense-reduce, 4096 rows -> 64)",
    ),
}

GENERIC_STRUCTURE = {
    # Operator skeletons of the recursive rules — the logical plan is the
    # execution contract now, so its shape is pinned alongside the notes.
    "transitive-closure": {
        "T2": ("T2", "tc", ("Project", ("Join", ("ScanState",), ("ScanEDB",)))),
    },
    "connected-components": {
        "C2": ("C2", "cc", ("GroupBy", ("Join", ("ScanState",), ("ScanEDB",)))),
    },
    "pagerank-threshold": {
        "P4": ("P4", "rankF", ("Frontier",)),
        "H2": ("H2", "reach",
               ("Project",
                ("Join",
                 ("Join", ("ScanState",), ("ScanEDB",)),
                 ("ScanState",)))),
    },
}


def _generic_executables():
    import numpy as np

    from repro.core.executor import Relation, compile_program
    from repro.core.listings import (
        connected_components_program,
        pagerank_threshold_program,
        same_generation_program,
        transitive_closure_program,
    )

    n = GENERIC_N
    rng = np.random.default_rng(0)
    src, dst = rng.integers(0, n, 96), rng.integers(0, n, 96)
    edge = Relation.from_columns(n, src, dst)
    node2 = Relation.from_columns(
        n, np.arange(n), np.arange(n, dtype=np.float32)
    )
    deg = np.bincount(src, minlength=n).astype(np.float32)
    node4 = Relation.from_columns(
        n, np.arange(n), np.full(n, 1.0 / n, np.float32), deg,
        np.full(n, 0.15 / n, np.float32),
    )
    out = {}
    for (name, semi_naive), prog, rels in (
        (("transitive-closure", False), transitive_closure_program(),
         {"edge": edge}),
        (("connected-components", False), connected_components_program(),
         {"edge": edge, "node": node2}),
        (("connected-components", True), connected_components_program(),
         {"edge": edge, "node": node2}),
        (("same-generation", False), same_generation_program(),
         {"parent": edge}),
        (("pagerank-threshold", False), pagerank_threshold_program(),
         {"edge": edge, "node": node4}),
    ):
        out[(name, semi_naive)] = compile_program(
            prog, rels, semi_naive=semi_naive
        )
    return out


def test_generic_program_plan_notes_golden():
    for key, ex in _generic_executables().items():
        assert ex.plan.notes == GENERIC_GOLDEN[key], (key, ex.plan.notes)


def test_generic_program_logical_structure_golden():
    for key, ex in _generic_executables().items():
        name, semi_naive = key
        want = GENERIC_STRUCTURE.get(name)
        if want is None or semi_naive:
            continue
        got = {df.label: df.structure() for df in ex.logical.body}
        for label, structure in want.items():
            assert got[label] == structure, (name, label, got[label])


def test_high_domain_tc_storage_selection_golden():
    # A 65536-vertex TC over sparse RowRelation edges: the cost model must
    # pick row tables for both predicates (the dense n^2 grid is 2^32
    # cells) and the note pins the chosen slab capacities — the EDB cap
    # from the real 57344-row count, the recursive cap at the slab ceiling.
    from repro.core.executor import RowRelation, compile_program
    from repro.core.listings import transitive_closure_program

    n, block = 65536, 8
    src = np.concatenate(
        [np.arange(s, s + block - 1) for s in range(0, n, block)])
    ex = compile_program(
        transitive_closure_program(),
        {"edge": RowRelation.from_columns(n, src, src + 1)},
    )
    assert ex.storage == {"edge": "row-table", "tc": "row-table"}
    assert ex.plan.notes == (
        "storage-selection(n=65536, edge=row-table[cap=524288], "
        "tc=row-table[cap=1048576])",
        "loop-invariant-caching(edb-grids)",
    )


# ---------------------------------------------------------------------------
# Parsed-text programs + the rewrite pass
# ---------------------------------------------------------------------------
#
# The text frontend's plans are pinned twice: rewrite-off must reproduce
# the hand-built GENERIC_GOLDEN notes byte-for-byte (the frontend adds no
# planning surface of its own), and rewrite-on must append exactly one
# rewrite(...) entry recording which of the three rewrites fired.  Each
# rewrite demonstrably fires on at least one program: join-reorder on
# TC/CC/pagerank, select-pushdown on negated-reach, CSE on same-generation.

GENERIC_REWRITE_GOLDEN = {
    ("transitive-closure", False): GENERIC_GOLDEN[
        ("transitive-closure", False)] + (
        "rewrite(join-reorder: T2, pushdown: none, cse: 0 shared)",
    ),
    ("connected-components", False): GENERIC_GOLDEN[
        ("connected-components", False)] + (
        "rewrite(join-reorder: C2, pushdown: none, cse: 0 shared)",
    ),
    # The rewrite entry lands after the semi-naive entries: with the
    # iterated program-cardinality estimates, Δcc reads ~1/8 of cc's real
    # ~64-row count — smaller than the 96-row edge relation, so the
    # source order (delta first) is already optimal and no reorder fires.
    ("connected-components", True): GENERIC_GOLDEN[
        ("connected-components", True)] + (
        "rewrite(join-reorder: none, pushdown: none, cse: 0 shared)",
    ),
    ("same-generation", False): GENERIC_GOLDEN[
        ("same-generation", False)] + (
        "rewrite(join-reorder: none, pushdown: none, cse: 1 shared)",
    ),
    ("pagerank-threshold", False): GENERIC_GOLDEN[
        ("pagerank-threshold", False)] + (
        "rewrite(join-reorder: P2+P3, pushdown: none, cse: 0 shared)",
    ),
    ("negated-reach", False): (
        "storage-selection(dense-grid[n=64])",
        "loop-invariant-caching(edb-grids)",
        "rewrite(join-reorder: none, pushdown: 1 select, cse: 0 shared)",
    ),
}

GENERIC_REWRITE_STRUCTURE = {
    # Join-reorder flips T2 to scan the 96-row edge relation before the
    # 4096-cell recursive state grid.
    "transitive-closure": {
        "T2": ("T2", "tc", ("Project", ("Join", ("ScanEDB",), ("ScanState",)))),
    },
    # Select-pushdown sinks the W < 3 guard below the AntiJoin into its
    # positive side; the negated blocked(Y) scan is untouched.
    "negated-reach": {
        "N2": ("N2", "reach",
               ("Project",
                ("AntiJoin",
                 ("Join",
                  ("Join", ("ScanState",), ("ScanEDB",)),
                  ("Select", ("ScanEDB",))),
                 ("ScanEDB",)))),
    },
}


def _parsed_executables(rewrite):
    import numpy as np

    from repro.core.executor import Relation, compile_program
    from repro.core.listings import (
        parsed_connected_components_program,
        parsed_negated_reach_program,
        parsed_pagerank_threshold_program,
        parsed_same_generation_program,
        parsed_transitive_closure_program,
    )

    n = GENERIC_N
    rng = np.random.default_rng(0)
    src, dst = rng.integers(0, n, 96), rng.integers(0, n, 96)
    edge = Relation.from_columns(n, src, dst)
    node2 = Relation.from_columns(
        n, np.arange(n), np.arange(n, dtype=np.float32)
    )
    deg = np.bincount(src, minlength=n).astype(np.float32)
    node4 = Relation.from_columns(
        n, np.arange(n), np.full(n, 1.0 / n, np.float32), deg,
        np.full(n, 0.15 / n, np.float32),
    )
    source = Relation.from_columns(
        n, np.arange(8),
        np.array([1, 0, 1, 1, 0, 1, 0, 1], np.float32),
    )
    blocked = Relation.from_columns(n, np.array([3, 9, 27]))
    nodew = Relation.from_columns(
        n, np.arange(n), (np.arange(n) % 5).astype(np.float32)
    )
    out = {}
    for (name, semi_naive), prog, rels in (
        (("transitive-closure", False),
         parsed_transitive_closure_program(), {"edge": edge}),
        (("connected-components", False),
         parsed_connected_components_program(),
         {"edge": edge, "node": node2}),
        (("connected-components", True),
         parsed_connected_components_program(),
         {"edge": edge, "node": node2}),
        (("same-generation", False),
         parsed_same_generation_program(), {"parent": edge}),
        (("pagerank-threshold", False),
         parsed_pagerank_threshold_program(),
         {"edge": edge, "node": node4}),
        (("negated-reach", False),
         parsed_negated_reach_program(),
         {"source": source, "edge": edge, "node": nodew,
          "blocked": blocked}),
    ):
        out[(name, semi_naive)] = compile_program(
            prog, rels, semi_naive=semi_naive, rewrite=rewrite
        )
    return out


def test_parsed_program_rewrite_off_matches_hand_built_notes():
    """PR 5's hand-built golden notes ARE the parsed programs' notes when
    the rewrite pass is off — the frontend adds zero planning surface."""

    for key, ex in _parsed_executables(rewrite=False).items():
        if key in GENERIC_GOLDEN:
            assert ex.plan.notes == GENERIC_GOLDEN[key], (key, ex.plan.notes)
        else:  # negated-reach is new in this PR; pin it directly.
            assert ex.plan.notes == GENERIC_REWRITE_GOLDEN[key][:-1], (
                key, ex.plan.notes)


def test_parsed_program_rewrite_on_notes_golden():
    for key, ex in _parsed_executables(rewrite=True).items():
        assert ex.plan.notes == GENERIC_REWRITE_GOLDEN[key], (
            key, ex.plan.notes)


# ---------------------------------------------------------------------------
# Explicit sharded exchanges + out-of-core chunking (PR 10)
# ---------------------------------------------------------------------------
#
# The exchange(...) / chunking(...) notes are the planner's public record of
# the PR-10 physical decisions — which Join/GroupBy sites leave GSPMD for the
# explicit key-hash bucket all-to-all (and its per-shard receiver capacity),
# where the monoid admits the psum-scatter fast path, and which EDB slabs
# stream host-resident chunks through the fixpoint step.  Pinned straight
# through plan_program so no device mesh is needed.

_X_PREDICATES = {"edge": (2, 3e6), "tc": (2, 1e7), "rank": (1, 16384.0)}
_X_KW = dict(
    predicates=_X_PREDICATES,
    storage={"rank": "row-table"},
    exchange_ops={"tc": None, "rank": "sum"},
    edb=("edge",),
    row_value_cols={"edge": 0},
)


def test_exchange_and_chunking_plan_notes_golden():
    from repro.core.planner import plan_program

    plan = plan_program(
        (("rank", "tc"),), (), 1 << 20, MeshSpec((("data", 8),)),
        hbm_budget=1 << 22, **_X_KW,
    )
    assert plan.notes == (
        "storage-selection(n=1048576, edge=row-table[cap=1048576], "
        "rank=row-table[cap=131072], tc=row-table[cap=1048576])",
        "loop-invariant-caching(edb-grids)",
        "spmd(gspmd data-parallel x8)",
        "exchange(edge: bucket-a2a[cap=1048576])",
        "exchange(rank: psum-scatter)",
        "exchange(tc: bucket-a2a[cap=1048576])",
        "chunking(edge: 3 chunks, budget=4194304B)",
    ), plan.notes
    assert plan.exchanges == {
        "edge": "bucket-a2a", "rank": "psum-scatter", "tc": "bucket-a2a"}
    assert plan.exchange_caps == {
        "edge": 1048576, "rank": 16384, "tc": 1048576}
    assert plan.chunks == {"edge": 3}


def test_single_shard_plan_has_no_exchange_notes():
    """dp=1 (and dp>1 under the default HBM budget) must not grow new
    notes — every pre-PR-10 golden snapshot above stays byte-identical."""

    from repro.core.planner import plan_program

    plan = plan_program(
        (("rank", "tc"),), (), 1 << 20, MESHES["1way"], **_X_KW,
    )
    assert not any(
        n.startswith(("exchange(", "chunking(")) for n in plan.notes
    ), plan.notes
    assert plan.exchanges == {} and plan.chunks == {}


def test_exchange_caps_divide_estimate_by_shard_count():
    """The bucket-a2a receiver capacity is sized from the planner's global
    cardinality estimate divided across the data shards (then rounded to a
    power of two, clamped to the slab cap) — more shards, smaller
    per-shard buckets."""

    from repro.core.planner import plan_program

    caps = {
        dp: plan_program(
            (("rank", "tc"),), (), 1 << 20, MeshSpec((("data", dp),)),
            **_X_KW,
        ).exchange_caps["rank"]
        for dp in (2, 4, 8)
    }
    assert caps == {2: 65536, 4: 32768, 8: 16384}


def test_parsed_program_rewrite_structure_golden():
    for key, ex in _parsed_executables(rewrite=True).items():
        name, semi_naive = key
        want = GENERIC_REWRITE_STRUCTURE.get(name)
        if want is None or semi_naive:
            continue
        got = {df.label: df.structure() for df in ex.logical.body}
        for label, structure in want.items():
            assert got[label] == structure, (name, label, got[label])
