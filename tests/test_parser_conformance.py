"""Differential conformance: parsed-text programs == hand-built ASTs.

The text frontend is only trustworthy if a parsed program *executes*
identically to the hand-built listing it mirrors, so every shipped
workload runs both forms through the same engine and compares converged
state to <= 1e-8 — on the host driver and the jitted device driver, with
the rewrite pass off AND on (rewrite-on must change plans, never
results).  Listing 1/2 text forms additionally dispatch onto the
specialized Pregel/IMRU fast paths with byte-identical plan notes.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.executor import Relation, compile_program
from repro.core.imru import IMRUTask, compile_imru
from repro.core.listings import (
    connected_components_program,
    negated_reach_program,
    pagerank_threshold_program,
    parsed_connected_components_program,
    parsed_imru_program,
    parsed_negated_reach_program,
    parsed_pagerank_threshold_program,
    parsed_pregel_program,
    parsed_same_generation_program,
    parsed_transitive_closure_program,
    same_generation_program,
    transitive_closure_program,
)
from repro.core.monoid import get_monoid
from repro.core.pregel import Graph, VertexProgram, compile_pregel

N = 64


def _relations():
    rng = np.random.default_rng(0)
    src, dst = rng.integers(0, N, 96), rng.integers(0, N, 96)
    edge = Relation.from_columns(N, src, dst)
    node2 = Relation.from_columns(
        N, np.arange(N), np.arange(N, dtype=np.float32))
    deg = np.bincount(src, minlength=N).astype(np.float32)
    node4 = Relation.from_columns(
        N, np.arange(N), np.full(N, 1.0 / N, np.float32), deg,
        np.full(N, 0.15 / N, np.float32))
    source = Relation.from_columns(
        N, np.arange(8), np.array([1, 0, 1, 1, 0, 1, 0, 1], np.float32))
    blocked = Relation.from_columns(N, np.array([3, 9, 27]))
    nodew = Relation.from_columns(
        N, np.arange(N), (np.arange(N) % 5).astype(np.float32))
    return {
        "edge": edge, "node2": node2, "node4": node4,
        "source": source, "blocked": blocked, "nodew": nodew,
    }


CASES = {
    "transitive-closure": (
        transitive_closure_program, parsed_transitive_closure_program,
        lambda r: {"edge": r["edge"]}, False),
    "connected-components": (
        connected_components_program, parsed_connected_components_program,
        lambda r: {"edge": r["edge"], "node": r["node2"]}, False),
    "connected-components/semi-naive": (
        connected_components_program, parsed_connected_components_program,
        lambda r: {"edge": r["edge"], "node": r["node2"]}, True),
    "same-generation": (
        same_generation_program, parsed_same_generation_program,
        lambda r: {"parent": r["edge"]}, False),
    "pagerank-threshold": (
        pagerank_threshold_program, parsed_pagerank_threshold_program,
        lambda r: {"edge": r["edge"], "node": r["node4"]}, False),
    "negated-reach": (
        negated_reach_program, parsed_negated_reach_program,
        lambda r: {"source": r["source"], "edge": r["edge"],
                   "node": r["nodew"], "blocked": r["blocked"]}, False),
}


def _assert_states_match(a, b, tag):
    assert a.converged and b.converged, tag
    assert set(a.state) == set(b.state), tag
    for pred, st in a.state.items():
        st2 = b.state[pred]
        assert (np.asarray(st.present) == np.asarray(st2.present)).all(), \
            (tag, pred)
        for i in st.values:
            av = np.asarray(st.values[i])
            bv = np.asarray(st2.values[i])
            assert np.max(np.abs(av - bv)) <= 1e-8, (tag, pred, i)


@pytest.mark.parametrize("rewrite", [False, True])
@pytest.mark.parametrize("case", sorted(CASES))
def test_parsed_program_matches_hand_built_on_host(case, rewrite):
    make_hand, make_parsed, pick, semi_naive = CASES[case]
    rels = pick(_relations())
    hand = compile_program(make_hand(), rels, semi_naive=semi_naive)
    parsed = compile_program(make_parsed(), rels, semi_naive=semi_naive,
                             rewrite=rewrite)
    a = hand.run(max_iters=80)
    b = parsed.run(max_iters=80)
    _assert_states_match(a, b, (case, rewrite))
    if rewrite:
        assert any(n.startswith("rewrite(") for n in parsed.plan.notes)
    else:
        # rewrite-off parses must carry the exact hand-built plan notes.
        assert parsed.plan.notes == hand.plan.notes


@pytest.mark.parametrize("rewrite", [False, True])
@pytest.mark.parametrize(
    "case", ["transitive-closure", "pagerank-threshold", "negated-reach"])
def test_parsed_program_matches_hand_built_on_device(case, rewrite):
    make_hand, make_parsed, pick, semi_naive = CASES[case]
    rels = pick(_relations())
    hand = compile_program(make_hand(), rels, semi_naive=semi_naive)
    parsed = compile_program(make_parsed(), rels, semi_naive=semi_naive,
                             rewrite=rewrite)
    a = hand.run(max_iters=80, on_device=True)
    b = parsed.run(max_iters=80, on_device=True)
    _assert_states_match(a, b, (case, rewrite, "device"))


# ---------------------------------------------------------------------------
# Listing 1/2 text forms ride the specialized fast paths
# ---------------------------------------------------------------------------


def _pagerank_vp():
    return VertexProgram(
        init_vertex=lambda ids, vd: jnp.stack(
            [jnp.full((N,), 1.0 / N), vd], axis=1),
        message=lambda j, s, ed: s[:, 0] / jnp.maximum(s[:, 1], 1.0),
        apply=lambda j, s, inbox, got: (
            jnp.stack([0.15 / N + 0.85 * inbox, s[:, 1]], axis=1),
            jnp.ones(s.shape[0], jnp.bool_)),
        combine="sum",
    )


def _graph(seed=5):
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(N), 4).astype(np.int32)
    dst = rng.integers(0, N, 4 * N).astype(np.int32)
    outdeg = np.bincount(src, minlength=N).astype(np.float32)
    return Graph(N, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(outdeg))


def test_parsed_pregel_text_rides_fast_path():
    vp, g = _pagerank_vp(), _graph()
    parsed = parsed_pregel_program(
        udfs={"init_vertex": vp.init_vertex, "update": vp.apply},
        aggregates={"combine":
                    get_monoid("sum").as_aggregate(recomputable=True)},
    )
    spec = compile_pregel(vp, g)
    gen = compile_program(parsed, {"data": g}, binding=vp)
    assert type(gen).__name__ == "PregelExecutable"
    assert gen.plan.notes == spec.plan.notes  # byte-identical
    a = spec.run(max_iters=12)
    b = gen.run(max_iters=12)
    assert a.iterations == b.iterations
    assert float(jnp.max(jnp.abs(a.state[0] - b.state[0]))) <= 1e-8


def test_parsed_imru_text_rides_fast_path():
    rng = np.random.default_rng(7)
    X = rng.normal(size=(256, 8)).astype(np.float32)
    w = rng.normal(size=8).astype(np.float32)
    y = X @ w
    task = IMRUTask(
        init_model=lambda: jnp.zeros(8, jnp.float32),
        map=lambda rec, m: (rec["x"] @ m - rec["y"]) @ rec["x"],
        update=lambda j, m, g: m - 1e-3 * g,
        tol=1e-9,
    )
    recs = {"x": jnp.asarray(X), "y": jnp.asarray(y)}
    parsed = parsed_imru_program(
        udfs={"init_model": task.init_model, "map": task.map,
              "update": task.update},
        aggregates={"reduce": task.reduce},
    )
    spec = compile_imru(task, recs)
    gen = compile_program(parsed, {"training_data": recs}, binding=task)
    assert type(gen).__name__ == "IMRUExecutable"
    assert gen.plan.notes == spec.plan.notes  # byte-identical
    a = spec.run(max_iters=80)
    b = gen.run(max_iters=80)
    assert a.iterations == b.iterations
    assert float(jnp.max(jnp.abs(a.state - b.state))) <= 1e-8
