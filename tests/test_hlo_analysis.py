"""HLO census tests: trip-count correction + collective parsing."""

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.launch.hlo_analysis import analyze_hlo, roofline_terms


def test_scan_flops_match_unrolled():
    def unrolled(x, w):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, None, length=8)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    expected = 2 * 128 * 256 * 256 * 8
    for f in (unrolled, scanned):
        c = jax.jit(f).lower(x, w).compile()
        census = analyze_hlo(c.as_text(), 1, 0)
        assert census.dot_flops == expected
    # and the scanned one recovered the trip count
    c = jax.jit(scanned).lower(x, w).compile()
    census = analyze_hlo(c.as_text(), 1, 0)
    assert 8 in census.while_trips.values()


def test_nested_scan_flops_multiply():
    def nested(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(nested).lower(x, w).compile()
    census = analyze_hlo(c.as_text(), 1, 0)
    assert census.dot_flops == 2 * 64 * 64 * 64 * 15


_FAKE_HLO = """\
HloModule test

%region_add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ar = f32[64,128]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[32,16]<=[512], use_global_device_ids=true, to_apply=%region_add
  %ag = f32[64,128]{1,0} all-gather(%p0), channel_id=2, replica_groups=[256,2]<=[2,256]T(1,0), dimensions={0}, use_global_device_ids=true
  ROOT %out = f32[64,128]{1,0} add(%ar, %ag)
}
"""


def test_collective_parsing_link_attribution():
    # 512 devices as (pod=2, data=16, model=16): pod stride = 256.
    census = analyze_hlo(_FAKE_HLO, 512, pod_stride=256)
    nbytes = 64 * 128 * 4
    # all-reduce over groups of 16 consecutive ids -> intra-pod (ICI)
    assert census.by_type_bytes["all-reduce"] == nbytes
    # all-gather groups from [2,256]T(1,0): members {i, i+256} -> cross-pod
    assert census.by_type_bytes["all-gather"] == nbytes / 2
    assert census.dcn_link_bytes > 0
    ar = [d for d in census.details if d["kind"] == "all-reduce"][0]
    ag = [d for d in census.details if d["kind"] == "all-gather"][0]
    assert not ar["crosses_pod"]
    assert ag["crosses_pod"]


def test_roofline_terms_dominance():
    census = analyze_hlo(_FAKE_HLO, 512, pod_stride=256)
    census.dot_flops = 197e12 * 2.0          # 2 s of compute
    census.bytes_accessed = 819e9 * 0.5      # 0.5 s of memory
    terms = roofline_terms(census, 512)
    assert terms["dominant"] == "compute_s"
    assert abs(terms["compute_s"] - 2.0) < 1e-6
