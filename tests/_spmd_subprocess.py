"""Shared launcher for the multi-device subprocess test programs.

The SPMD suites need 8 fake devices (``XLA_FLAGS`` set before jax imports)
while the main pytest process must keep seeing 1 — per the dry-run
contract — so each suite runs a standalone program in a subprocess and
parses its ``RESULTS_JSON:`` line.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys


def run_spmd_program(filename: str) -> dict:
    """Run ``tests/<filename>`` in a subprocess and return its results dict.

    Retries once on collective-rendezvous aborts: XLA CPU kills a collective
    if a participant thread is starved for 40 s (8 virtual devices share one
    physical core on CI), so transient machine load can abort a first run.
    """

    tests_dir = os.path.dirname(os.path.abspath(__file__))
    prog = os.path.join(tests_dir, filename)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(tests_dir), "src")
    for attempt in (1, 2):
        proc = subprocess.run(
            [sys.executable, prog], capture_output=True, text=True, env=env,
            timeout=1800,
        )
        if proc.returncode == 0:
            break
        if attempt == 2 or "rendezvous" not in proc.stderr.lower():
            assert proc.returncode == 0, proc.stderr[-4000:]
    lines = [l for l in proc.stdout.splitlines()
             if l.startswith("RESULTS_JSON:")]
    assert lines, f"no RESULTS_JSON line in {filename} output:\n" \
                  f"{proc.stdout[-2000:]}"
    return json.loads(lines[-1][len("RESULTS_JSON:"):])
