"""Shared monoid workloads + NumPy oracles for the aggregate-algebra suites.

Each of the four generalized aggregates ships with a workload that exercises
it end-to-end through the Pregel stack, paired with a pure-NumPy re-
implementation of the same superstep semantics (vote-to-halt included).
The oracles are deliberately *independent* code — python loops over edges
and vertices, float64 accumulation — so a conformance failure implicates
the engine, not a shared helper.

* ``argmin``     — weighted SSSP with parent pointers (spanning tree).
* ``topk``       — top-k value propagation (k-truncated personalized-
                   PageRank-style: every vertex tracks the k largest
                   reachable seed values).
* ``mean``       — label propagation / Adsorption-style averaging.
* ``logsumexp``  — log-space diffusion (softmax-weighted pooling).

Used by ``tests/test_monoids.py`` (single shard) and
``tests/spmd_monoid_program.py`` (8 virtual devices, subprocess).
"""

from __future__ import annotations

import numpy as np

TOPK_K = 4
INF = 1e9


def make_graph(n: int, seed: int = 3):
    """Random multigraph with every vertex reachable-ish: ~3 out-edges per
    vertex plus one guaranteed in-edge per vertex.  Weights are exact binary
    fractions so min/argmin relaxations are bit-exact across paths."""

    rng = np.random.default_rng(seed)
    src, dst = [], []
    for v in range(n):
        for _ in range(int(rng.integers(2, 5))):
            src.append(v)
            dst.append(int(rng.integers(0, n)))
    for v in range(n):
        src.append(int(rng.integers(0, n)))
        dst.append(v)
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    weights = (((np.arange(len(src)) % 7) + 1) * 0.25).astype(np.float64)
    return src, dst, weights


# ---------------------------------------------------------------------------
# NumPy combine oracles (one row at a time)
# ---------------------------------------------------------------------------


def np_combines():
    return {
        "sum": lambda a, b: a + b,
        "max": np.maximum,
        "min": np.minimum,
        "argmin": lambda a, b: a if tuple(a) <= tuple(b) else b,
        "topk": lambda a, b: np.sort(np.concatenate([a, b]))[::-1][: len(a)],
        "mean": lambda a, b: a + b,
        "logsumexp": np.logaddexp,
    }


def np_identity(name, width):
    if name == "argmin":
        return np.concatenate([[np.inf], np.zeros(width - 1)])
    return {
        "sum": np.zeros(width), "mean": np.zeros(width),
        "max": np.full(width, -np.inf), "min": np.full(width, np.inf),
        "topk": np.full(width, -np.inf),
        "logsumexp": np.full(width, -np.inf),
    }[name]


def numpy_pregel(src, dst, weights, n, state0, msg_fn, combine_fn,
                 apply_fn, finalize_fn, iters, active0=None):
    """Reference Pregel loop: messages from active sources only, per-
    destination fold with ``combine_fn``, got-gated apply and halt — the
    exact merge semantics of ``repro.core.pregel._apply_and_merge``.

    ``msg_fn(j, state_row, weight) -> row``; ``apply_fn(j, state_row,
    inbox_row, got) -> (new_row, active)`` is called per vertex with
    ``inbox_row=None`` when no message arrived.  Returns (state, converged,
    n_iters)."""

    state = np.array(state0, np.float64, copy=True)
    active = (np.ones(n, bool) if active0 is None
              else np.asarray(active0, bool).copy())
    e_count = len(src)
    for j in range(iters):
        inbox = {}
        for e in range(e_count):
            s = int(src[e])
            if not active[s]:
                continue
            m = np.asarray(
                msg_fn(j, state[s], None if weights is None else weights[e]),
                np.float64,
            )
            d = int(dst[e])
            inbox[d] = m if d not in inbox else combine_fn(inbox[d], m)
        if not inbox:
            active[:] = False
            return state, True, j + 1
        new_active = np.zeros(n, bool)
        for d, acc in inbox.items():
            fin = acc if finalize_fn is None else finalize_fn(acc)
            new_row, act = apply_fn(j, state[d], fin, True)
            state[d] = new_row
            new_active[d] = act
        active = new_active
        if not active.any():
            return state, True, j + 1
    return state, False, iters


def numpy_superstep(src, dst, weights, n, state, active, msg_fn,
                    combine_fn, apply_fn, finalize_fn):
    """One got-gated superstep (same semantics as :func:`numpy_pregel`),
    returning (new_state, new_active)."""

    out, _, _ = numpy_pregel(
        src, dst, weights, n, state, msg_fn, combine_fn, apply_fn,
        finalize_fn, iters=1, active0=active,
    )
    # Recompute new_active exactly: run the loop body again for the flags.
    st = np.array(state, np.float64, copy=True)
    inbox = {}
    for e in range(len(src)):
        s = int(src[e])
        if not active[s]:
            continue
        m = np.asarray(
            msg_fn(0, st[s], None if weights is None else weights[e]),
            np.float64,
        )
        d = int(dst[e])
        inbox[d] = m if d not in inbox else combine_fn(inbox[d], m)
    new_active = np.zeros(n, bool)
    for d, acc in inbox.items():
        fin = acc if finalize_fn is None else finalize_fn(acc)
        _, act = apply_fn(0, st[d], fin, True)
        new_active[d] = act
    return out, new_active


# ---------------------------------------------------------------------------
# Workloads: jax VertexProgram + the matching NumPy pieces
# ---------------------------------------------------------------------------


def build_workloads(n: int, dtype=None):
    """Returns ``{name: spec}`` where spec has the jax ``prog`` (a
    VertexProgram), ``iters``, ``weighted`` (bool: message reads edge
    weights), plus the NumPy oracle pieces (``np_state0`` f64 [n, ...],
    ``np_msg``, ``np_apply``, ``np_finalize``, ``combine`` name).

    ``dtype`` defaults to f32; the SPMD conformance program passes f64
    (with jax_enable_x64) so cross-shard reassociation error stays under
    the 1e-8 bar even for logsumexp/mean.
    """

    import jax.numpy as jnp
    from repro.core.pregel import VertexProgram

    dtype = dtype or jnp.float32
    rng = np.random.default_rng(11)
    seeds = rng.standard_normal(n) * 3.0
    k = TOPK_K

    # -- argmin: weighted SSSP with parent pointers -------------------------
    # state [n, 3] = (dist, parent, self id); message (dist + w, self id).
    def sssp_init(ids, vd):
        dist = jnp.where(ids == 0, 0.0, INF).astype(dtype)
        return jnp.stack(
            [dist, jnp.full((n,), -1.0, dtype), ids.astype(dtype)], axis=1
        )

    def sssp_message(j, s, ed):
        return jnp.stack([s[:, 0] + ed, s[:, 2]], axis=1)

    def sssp_apply(j, s, inbox, got):
        better = inbox[:, 0] < s[:, 0]
        head = jnp.where(better[:, None], inbox, s[:, :2])
        return jnp.concatenate([head, s[:, 2:]], axis=1), better

    argmin_state0 = np.stack(
        [np.where(np.arange(n) == 0, 0.0, INF),
         np.full(n, -1.0), np.arange(n, dtype=np.float64)], axis=1
    )

    def argmin_np_msg(j, srow, w):
        return np.array([srow[0] + w, srow[2]])

    def argmin_np_apply(j, srow, inbox, got):
        if inbox[0] < srow[0]:
            return np.concatenate([inbox, srow[2:]]), True
        return srow, False

    # -- topk: k largest reachable seed values ------------------------------
    def topk_init(ids, vd):
        base = jnp.full((n, k), -jnp.inf, dtype)
        return base.at[:, 0].set(jnp.asarray(seeds, dtype))

    def topk_merge(a, b):
        return jnp.sort(jnp.concatenate([a, b], axis=1), axis=1)[:, ::-1][:, :k]

    def topk_apply(j, s, inbox, got):
        merged = topk_merge(s, inbox)
        return merged, jnp.any(merged != s, axis=1)

    topk_state0 = np.full((n, k), -np.inf)
    topk_state0[:, 0] = seeds

    def topk_np_apply(j, srow, inbox, got):
        merged = np.sort(np.concatenate([srow, inbox]))[::-1][:k]
        return merged, not np.array_equal(merged, srow)

    # -- mean: label propagation (Adsorption-style averaging) ---------------
    def mean_init(ids, vd):
        return jnp.asarray(seeds, dtype)

    def mean_message(j, s, ed):
        return jnp.stack([s, jnp.ones_like(s)], axis=1)

    def mean_apply(j, s, inbox, got):
        return 0.5 * s + 0.5 * inbox, jnp.ones(s.shape[0], jnp.bool_)

    def mean_np_finalize(acc):
        return acc[0] / max(acc[1], 1.0)

    def mean_np_apply(j, srow, inbox, got):
        return 0.5 * srow + 0.5 * inbox, True

    # -- logsumexp: log-space diffusion -------------------------------------
    def lse_init(ids, vd):
        return jnp.asarray(seeds, dtype)

    def lse_apply(j, s, inbox, got):
        return inbox, jnp.ones(s.shape[0], jnp.bool_)

    def passthrough_np_msg(j, srow, w):
        return srow

    return {
        "argmin_sssp": dict(
            prog=VertexProgram(sssp_init, sssp_message, sssp_apply,
                               combine="argmin", name="sssp-parents"),
            iters=4 * n, weighted=True, combine="argmin",
            np_state0=argmin_state0, np_msg=argmin_np_msg,
            np_apply=argmin_np_apply, np_finalize=None,
        ),
        "topk_prop": dict(
            prog=VertexProgram(topk_init, lambda j, s, ed: s, topk_apply,
                               combine="topk", name="topk-prop"),
            iters=4 * n, weighted=False, combine="topk",
            np_state0=topk_state0, np_msg=passthrough_np_msg,
            np_apply=topk_np_apply, np_finalize=None,
        ),
        "mean_labelprop": dict(
            prog=VertexProgram(mean_init, mean_message, mean_apply,
                               combine="mean", name="label-prop"),
            iters=6, weighted=False, combine="mean",
            np_state0=seeds.astype(np.float64),
            np_msg=lambda j, srow, w: np.array([srow, 1.0]),
            np_apply=mean_np_apply, np_finalize=mean_np_finalize,
        ),
        "logsumexp_diffusion": dict(
            prog=VertexProgram(lse_init, lambda j, s, ed: s, lse_apply,
                               combine="logsumexp", name="lse-diffusion"),
            iters=4, weighted=False, combine="logsumexp",
            np_state0=seeds.astype(np.float64),
            np_msg=passthrough_np_msg,
            np_apply=lambda j, srow, inbox, got: (inbox, True),
            np_finalize=None,
        ),
    }


def finite(x, neg=-1e30):
    """Map -inf to a finite sentinel (in f64!) so |a - b| comparisons work
    on topk/logsumexp states that legitimately hold -inf."""

    x = np.asarray(x, np.float64)
    return np.where(np.isneginf(x), neg, x)
