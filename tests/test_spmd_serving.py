"""Sharded serving differential conformance (subprocess: needs 8 fake
devices while the main pytest process must keep seeing 1 — same contract
as test_spmd.py).

The subprocess (spmd_serving_program.py) serves personalized-PageRank and
point-reachability batches on an 8-virtual-device data mesh through
:class:`repro.core.serving.FixpointServer` and compares batched-vmap,
sharded-sequential, and single-device answers; these tests assert on its
JSON report with the 1e-8 acceptance bar, plus the mesh-topology facet of
the plan-cache key.
"""

import pytest

from _spmd_subprocess import run_spmd_program


@pytest.fixture(scope="module")
def serving_results():
    return run_spmd_program("spmd_serving_program.py")


def test_runs_on_eight_devices(serving_results):
    assert serving_results["devices"] == 8


def test_sharded_batched_matches_sequential(serving_results):
    assert serving_results["ppr_batched_dispatch"]
    assert serving_results["ppr_batched_vs_sequential"] <= 1e-8


def test_sharded_matches_single_device(serving_results):
    assert serving_results["ppr_sharded_vs_single_device"] <= 1e-8


def test_reachability_hit_sets_agree(serving_results):
    assert serving_results["reach_hits_agree"]


def test_plan_cache_keys_mesh_topology(serving_results):
    assert serving_results["meshed_warm_hit"]
    assert serving_results["mesh_changes_key"]
