"""Sharded serving conformance program, run as a subprocess by
test_spmd_serving.py (the XLA device-count flag must be set before jax
imports, and the main test process must keep seeing 1 device).

Properties defended on an 8-virtual-device data mesh:

* batched-vmap dispatch through ONE sharded fixpoint matches the
  sequential per-query answers to <= 1e-8 (personalized PageRank) and
  bit-exactly (point reachability hit sets);
* the sharded sequential answers themselves match a single-device
  server's answers to <= 1e-8 (the mesh does not change semantics);
* the plan cache keys the mesh topology: warm requests on the meshed
  server hit, and the meshed key differs from the unmeshed key.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import json

import numpy as np

N = 64
SEED_SETS = ([0], [5, 9], [17], [3, 40, 41])
PROBES = ((0, 33), (7, 7), (21, 2), (12, 63))


def _graph(n=N, deg=4, seed=2):
    from repro.core.executor import Relation

    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, n * deg)
    keep = src != dst
    pairs = sorted(set(zip(src[keep].tolist(), dst[keep].tolist())))
    src = np.array([p[0] for p in pairs])
    dst = np.array([p[1] for p in pairs])
    degree = np.bincount(src, minlength=n).astype(np.float32)
    return (Relation.from_columns(n, src, dst),
            Relation.from_columns(n, np.arange(n), degree))


def _seed_rel(vertices):
    from repro.core.executor import Relation

    vs = np.asarray(vertices)
    return Relation.from_columns(
        N, vs, np.full(len(vs), 1.0 / len(vs), np.float32))


def _unary(vertices):
    from repro.core.executor import Relation

    return Relation.from_columns(N, np.asarray(vertices))


def _rank(ans):
    rel = ans["rank"]
    return np.where(np.asarray(rel.present),
                    np.asarray(rel.values[1]), 0.0)


def main() -> None:
    import jax
    from repro.core.serving import (
        FixpointServer,
        personalized_pagerank_program,
        point_reachability_program,
    )
    from repro.launch.mesh import make_data_mesh

    results = {"devices": len(jax.devices())}
    edge, deg = _graph()
    mesh = make_data_mesh()
    meshed = FixpointServer({"edge": edge, "deg": deg}, mesh=mesh)
    single = FixpointServer({"edge": edge, "deg": deg})
    ppr = personalized_pagerank_program()
    reach = point_reachability_program()

    # --- PPR: sharded batched vs sharded sequential vs single-device ------
    batch = [{"seed": _seed_rel(vs)} for vs in SEED_SETS]
    b = meshed.query(ppr, batch, max_iters=8, force="batched")
    s = meshed.query(ppr, batch, max_iters=8, force="sequential")
    solo = single.query(ppr, batch, max_iters=8, force="sequential")
    results["ppr_batched_vs_sequential"] = max(
        float(np.abs(_rank(x) - _rank(y)).max())
        for x, y in zip(b.answers, s.answers))
    results["ppr_sharded_vs_single_device"] = max(
        float(np.abs(_rank(x) - _rank(y)).max())
        for x, y in zip(s.answers, solo.answers))
    results["ppr_batched_dispatch"] = bool(b.batched and not s.batched)

    # --- reachability: hit sets bit-equal across all three paths ----------
    probes = [{"src": _unary([a]), "dst": _unary([b_])}
              for a, b_ in PROBES]
    rb = meshed.query(reach, probes, max_iters=N, force="batched")
    rs = meshed.query(reach, probes, max_iters=N, force="sequential")
    rsolo = single.query(reach, probes, max_iters=N, force="sequential")
    results["reach_hits_agree"] = all(
        np.array_equal(np.asarray(x["hit"].present),
                       np.asarray(y["hit"].present))
        and np.array_equal(np.asarray(x["hit"].present),
                           np.asarray(z["hit"].present))
        for x, y, z in zip(rb.answers, rs.answers, rsolo.answers))

    # --- plan cache keys the mesh topology ---------------------------------
    warm = meshed.query(ppr, batch, max_iters=8, force="batched")
    results["meshed_warm_hit"] = bool(
        warm.cache_hit and warm.compile_seconds == 0.0)
    results["mesh_changes_key"] = (
        meshed.plan_key(ppr, ("seed",)) != single.plan_key(ppr, ("seed",)))

    print("RESULTS_JSON:" + json.dumps(results))


if __name__ == "__main__":
    main()
