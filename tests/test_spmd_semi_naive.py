"""Sharded semi-naive differential conformance (subprocess: needs 8 fake
devices while the main pytest process must keep seeing 1 — same contract as
test_spmd.py).

The subprocess (spmd_semi_naive_program.py) runs sharded delta-frontier
fixpoints for PageRank / SSSP / connected components across all three
connectors and sum/max/min combines, and compares them against single-shard
dense oracles; these tests assert on its JSON report.

Weighted graphs are first-class: ``Graph.edge_data`` is partitioned into
the per-shard edge slabs, so weighted SSSP and edge-weighted PageRank run
end-to-end on both sharded paths (dense shard_map superstep and the
frontier-compacted sparse superstep) and must match the single-shard dense
reference to <= 1e-8 on every connector.
"""

import pytest

from _spmd_subprocess import run_spmd_program


@pytest.fixture(scope="module")
def sharded_results():
    return run_spmd_program("spmd_semi_naive_program.py")


def test_sharded_sparse_fixpoints_match_single_shard_dense(sharded_results):
    for key, err in sharded_results["fixpoint_errs"].items():
        assert err < 1e-5, (key, err)


def test_sharded_meshes_support_sparse(sharded_results):
    assert sharded_results["supports_sparse"]
    assert all(sharded_results["supports_sparse"].values())


def test_collapsing_frontier_workloads_actually_go_sparse(sharded_results):
    engaged = sharded_results["sparse_engaged"]
    for name in ("sssp", "cc"):
        for conn in ("dense_psum", "merging", "hash_sort"):
            assert engaged[f"{name}/{conn}"], (name, conn)
    # PageRank keeps every vertex active: the collective mode agreement must
    # keep the whole mesh dense, never half-switch.
    assert not any(v for k, v in engaged.items() if k.startswith("pagerank/"))


def test_sharded_sparse_superstep_matches_dense_all_ops(sharded_results):
    for key, err in sharded_results["superstep_errs"].items():
        assert err < 1e-5, (key, err)


def test_weighted_fixpoints_match_single_shard_dense(sharded_results):
    # Weighted SSSP + edge-weighted PageRank, sharded dense AND sharded
    # sparse, all three connectors, vs the single-shard dense oracle.
    errs = sharded_results["weighted_errs"]
    for name in ("sssp_w", "pagerank_w"):
        for conn in ("dense_psum", "merging", "hash_sort"):
            for path in ("dense", "sparse"):
                key = f"{name}/{conn}/{path}"
                assert key in errs
                assert errs[key] <= 1e-8, (key, errs[key])


def test_weighted_collapsing_frontier_goes_sparse(sharded_results):
    # The sparse (compacted attribute gather) path must actually engage for
    # the collapsing-frontier weighted workload; edge-weighted PageRank
    # keeps every vertex active and must stay dense in SPMD lockstep.
    engaged = sharded_results["weighted_sparse_engaged"]
    for conn in ("dense_psum", "merging", "hash_sort"):
        assert engaged[f"sssp_w/{conn}"], conn
        assert not engaged[f"pagerank_w/{conn}"], conn


def test_weighted_sharded_sparse_superstep_matches_dense_all_ops(
        sharded_results):
    # The compacted slab's edge-attribute gather under every combine op
    # (sum never goes sparse in a full fixpoint, so it is pinned at the
    # superstep level).
    for key, err in sharded_results["weighted_superstep_errs"].items():
        assert err < 1e-5, (key, err)


def test_more_shards_than_edges_weighted_slabs(sharded_results):
    # 3 edges over 8 shards: mostly-padding weighted slabs must not wrap
    # the compacted-index clamp (regression for the empty-slab gather).
    assert sharded_results["tiny_weighted_converged"]
    assert sharded_results["tiny_weighted_err"] <= 1e-8


def test_empty_frontier_halts_sharded_fixpoint_early(sharded_results):
    assert sharded_results["halt_converged"]
    assert sharded_results["halt_last_mode"] == "halt(empty-frontier)"
    assert sharded_results["halt_sparse_engaged"]
    assert sharded_results["halt_err"] < 1e-6
    assert sharded_results["halt_active_cleared"]
