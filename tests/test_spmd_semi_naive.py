"""Sharded semi-naive differential conformance (subprocess: needs 8 fake
devices while the main pytest process must keep seeing 1 — same contract as
test_spmd.py).

The subprocess (spmd_semi_naive_program.py) runs sharded delta-frontier
fixpoints for PageRank / SSSP / connected components across all three
connectors and sum/max/min combines, and compares them against single-shard
dense oracles; these tests assert on its JSON report.
"""

import pytest

from _spmd_subprocess import run_spmd_program


@pytest.fixture(scope="module")
def sharded_results():
    return run_spmd_program("spmd_semi_naive_program.py")


def test_sharded_sparse_fixpoints_match_single_shard_dense(sharded_results):
    for key, err in sharded_results["fixpoint_errs"].items():
        assert err < 1e-5, (key, err)


def test_sharded_meshes_support_sparse(sharded_results):
    assert sharded_results["supports_sparse"]
    assert all(sharded_results["supports_sparse"].values())


def test_collapsing_frontier_workloads_actually_go_sparse(sharded_results):
    engaged = sharded_results["sparse_engaged"]
    for name in ("sssp", "cc"):
        for conn in ("dense_psum", "merging", "hash_sort"):
            assert engaged[f"{name}/{conn}"], (name, conn)
    # PageRank keeps every vertex active: the collective mode agreement must
    # keep the whole mesh dense, never half-switch.
    assert not any(v for k, v in engaged.items() if k.startswith("pagerank/"))


def test_sharded_sparse_superstep_matches_dense_all_ops(sharded_results):
    for key, err in sharded_results["superstep_errs"].items():
        assert err < 1e-5, (key, err)


def test_sharded_edge_data_rejected_loudly(sharded_results):
    # The sharded layouts do not partition edge_data yet; compiling must
    # raise instead of silently tracing the message UDF with None.
    assert sharded_results["edge_data_rejected"]


def test_empty_frontier_halts_sharded_fixpoint_early(sharded_results):
    assert sharded_results["halt_converged"]
    assert sharded_results["halt_last_mode"] == "halt(empty-frontier)"
    assert sharded_results["halt_sparse_engaged"]
    assert sharded_results["halt_err"] < 1e-6
    assert sharded_results["halt_active_cleared"]
