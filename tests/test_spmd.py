"""Multi-device SPMD tests (subprocess: needs 8 fake devices while the main
pytest process must keep seeing 1 — per the dry-run contract)."""

import json
import os
import subprocess
import sys

import pytest


@pytest.fixture(scope="module")
def spmd_results():
    prog = os.path.join(os.path.dirname(__file__), "spmd_program.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    # XLA CPU aborts a collective if a participant thread is starved for
    # 40 s (8 virtual devices share one physical core here) — retry once
    # to ride out transient machine load.
    for attempt in (1, 2):
        proc = subprocess.run(
            [sys.executable, prog], capture_output=True, text=True, env=env,
            timeout=1800,
        )
        if proc.returncode == 0:
            break
        if attempt == 2 or "rendezvous" not in proc.stderr.lower():
            assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULTS_JSON:")][-1]
    return json.loads(line[len("RESULTS_JSON:"):])


def test_all_reduce_schedules_reach_same_fixpoint(spmd_results):
    assert spmd_results["imru_schedules_agree"]
    assert spmd_results["imru_err_vs_true"] < 1e-3


def test_int8_error_feedback_converges(spmd_results):
    assert spmd_results["int8_ef_err_vs_true"] < 5e-2


def test_sharded_pregel_connectors_match_oracle(spmd_results):
    for conn, err in spmd_results["pregel_errs"].items():
        assert err < 1e-6, (conn, err)


def test_sharded_lm_train_step_runs_and_learns(spmd_results):
    assert spmd_results["lm_sharded_decreasing"], \
        spmd_results["lm_sharded_losses"]
