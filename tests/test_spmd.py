"""Multi-device SPMD tests (subprocess: needs 8 fake devices while the main
pytest process must keep seeing 1 — per the dry-run contract)."""

import pytest

from _spmd_subprocess import run_spmd_program


@pytest.fixture(scope="module")
def spmd_results():
    return run_spmd_program("spmd_program.py")


def test_all_reduce_schedules_reach_same_fixpoint(spmd_results):
    assert spmd_results["imru_schedules_agree"]
    assert spmd_results["imru_err_vs_true"] < 1e-3


def test_int8_error_feedback_converges(spmd_results):
    assert spmd_results["int8_ef_err_vs_true"] < 5e-2


def test_sharded_pregel_connectors_match_oracle(spmd_results):
    for conn, err in spmd_results["pregel_errs"].items():
        assert err < 1e-6, (conn, err)


def test_sharded_lm_train_step_runs_and_learns(spmd_results):
    assert spmd_results["lm_sharded_decreasing"], \
        spmd_results["lm_sharded_losses"]
