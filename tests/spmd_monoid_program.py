"""Sharded generalized-aggregate conformance program, run as a subprocess by
test_spmd_monoids.py (the XLA device-count flag must be set before jax
imports, and the main test process must keep seeing 1 device).

Property defended: on an 8-virtual-device SPMD mesh, each of the four
generalized aggregates — argmin (SSSP parent pointers), topk (k-truncated
value propagation), mean ((sum, count) label averaging), logsumexp — matches
an independent NumPy oracle to <= 1e-8 on the sharded DENSE path and the
sharded SPARSE (delta-frontier) path, across all three Fig.-9 connectors.

Everything runs in float64 (jax_enable_x64): the conformance bar is 1e-8,
and while argmin/topk are pure selections (bit-exact in any precision),
mean/logsumexp reassociate float additions across shard orders — f64 keeps
that reassociation error at the 1e-15 level instead of 1e-7.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import dataclasses
import json

import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp

from _monoid_workloads import (
    build_workloads,
    finite,
    make_graph,
    np_combines,
    numpy_pregel,
    numpy_superstep,
)

CONNECTORS = ("dense_psum", "merging", "hash_sort")
N = 64


def main() -> None:
    from repro.core.pregel import Graph, compile_pregel
    from repro.launch.mesh import make_data_mesh

    results = {}
    mesh = make_data_mesh()
    src, dst, weights = make_graph(N)
    workloads = build_workloads(N, dtype=jnp.float64)

    def graph_for(wl):
        edata = (jnp.asarray(weights) if wl["weighted"] else None)
        return Graph(N, jnp.asarray(src), jnp.asarray(dst),
                     jnp.zeros(N, jnp.float64), edge_data=edata)

    # --- fixpoint conformance: sharded dense AND sharded sparse vs NumPy ---
    errs = {}
    sparse_engaged = {}
    converged = {}
    for name, wl in workloads.items():
        ref, ref_conv, _ = numpy_pregel(
            src, dst, weights if wl["weighted"] else None, N,
            wl["np_state0"], wl["np_msg"], np_combines()[wl["combine"]],
            wl["np_apply"], wl["np_finalize"], wl["iters"],
        )
        g = graph_for(wl)
        for conn in CONNECTORS:
            dense_sh = compile_pregel(wl["prog"], g, mesh=mesh,
                                      force_connector=conn)
            r_dense = dense_sh.run(max_iters=wl["iters"])
            errs[f"{name}/{conn}/dense"] = float(np.max(np.abs(
                finite(r_dense.state[0]) - finite(ref))))
            ex = compile_pregel(wl["prog"], g, mesh=mesh,
                                force_connector=conn, semi_naive=True)
            # Pin the dense<->sparse policy so conformance does not depend
            # on the cost model's threshold for this tiny graph.
            ex.plan = dataclasses.replace(
                ex.plan, density_threshold=0.6, sparse_cap_floor=16)
            r_sparse = ex.run(max_iters=wl["iters"])
            errs[f"{name}/{conn}/sparse"] = float(np.max(np.abs(
                finite(r_sparse.state[0]) - finite(ref))))
            sparse_engaged[f"{name}/{conn}"] = any(
                m.startswith("sparse@") for m in r_sparse.modes)
            converged[f"{name}/{conn}"] = bool(
                r_dense.converged == ref_conv
                and r_sparse.converged == ref_conv)
    results["fixpoint_errs"] = errs
    results["sparse_engaged"] = sparse_engaged
    results["convergence_agrees"] = converged

    # --- superstep conformance on a pinned ~15% frontier -------------------
    # mean/logsumexp keep every vertex active, so their sparse path never
    # engages in a full fixpoint; pin a partial frontier and check one
    # sharded dense and one sharded frontier-compacted superstep against
    # the NumPy single-superstep oracle for every monoid x connector.
    rng = np.random.default_rng(9)
    active0 = np.zeros(N, bool)
    active0[rng.choice(N, max(1, N * 15 // 100), replace=False)] = True
    step_errs = {}
    for name, wl in workloads.items():
        g = graph_for(wl)
        ref_state, ref_active = numpy_superstep(
            src, dst, weights if wl["weighted"] else None, N,
            wl["np_state0"], active0, wl["np_msg"],
            np_combines()[wl["combine"]], wl["np_apply"],
            wl["np_finalize"],
        )
        for conn in CONNECTORS:
            ex = compile_pregel(wl["prog"], g, mesh=mesh,
                                force_connector=conn, semi_naive=True)
            ex.plan = dataclasses.replace(ex.plan, sparse_cap_floor=16)
            carry = (ex.init()[0], jnp.asarray(active0))
            for path, step in (
                ("dense", ex.jitted_superstep),
                ("sparse", ex.sparse_superstep(ex.sparse_cap_for(
                    int(ex.shard_edge_counts(carry[1]).max())))),
            ):
                st, ac = step(carry, jnp.int32(0))
                err = float(np.max(np.abs(finite(st) - finite(ref_state))))
                agree = bool(np.array_equal(np.asarray(ac), ref_active))
                step_errs[f"{name}/{conn}/{path}"] = (
                    err if agree else float("inf"))
    results["superstep_errs"] = step_errs

    print("RESULTS_JSON:" + json.dumps(results))


if __name__ == "__main__":
    main()
