"""Differential conformance for the unified logical-plan executor.

Two properties defended:

1. **One engine, many programs** — ``compile_program`` executes arbitrary
   XY-stratified programs (transitive closure, connected components,
   same-generation, and the multi-stratum PageRank→threshold→reach
   pipeline) matching independent NumPy oracles, on the host driver AND the
   on-device ``lax.while_loop`` driver, naive and semi-naive.

2. **Listings 1/2 through the unified entry point** — the planner selects
   the specialized fast paths for the paper's listing programs, so
   ``compile_program(listing, ..., binding=...)`` must produce outputs
   identical (≤1e-8) to ``compile_pregel`` / ``compile_imru`` on all three
   connectors, with the plan notes unchanged by the refactor.

The 8-virtual-device SPMD conformance lives in
``tests/test_spmd_executor.py`` (subprocess launcher).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.executor import (
    ExecutorError,
    Relation,
    compile_program,
)
from repro.core.imru import IMRUTask, compile_imru
from repro.core.listings import (
    connected_components_program,
    pagerank_threshold_program,
    same_generation_program,
    transitive_closure_program,
)
from repro.core.pregel import Graph, VertexProgram, compile_pregel

CONNECTORS = ("dense_psum", "merging", "hash_sort")
N = 32


# ---------------------------------------------------------------------------
# Shared fixtures
# ---------------------------------------------------------------------------


def _edges(seed=0, m=48):
    rng = np.random.default_rng(seed)
    return rng.integers(0, N, m), rng.integers(0, N, m)


def _tc_oracle(src, dst):
    adj = np.zeros((N, N), bool)
    adj[src, dst] = True
    tc = adj.copy()
    while True:
        new = tc | (tc @ adj)
        if (new == tc).all():
            return tc
        tc = new


# ---------------------------------------------------------------------------
# Generic programs vs NumPy oracles
# ---------------------------------------------------------------------------


def test_transitive_closure_matches_numpy_oracle():
    src, dst = _edges()
    ex = compile_program(
        transitive_closure_program(),
        {"edge": Relation.from_columns(N, src, dst)},
    )
    res = ex.run(max_iters=64)
    assert res.converged
    assert (np.asarray(res.state["tc"].present) == _tc_oracle(src, dst)).all()


def test_transitive_closure_device_driver_matches_host():
    src, dst = _edges(seed=3)
    ex = compile_program(
        transitive_closure_program(),
        {"edge": Relation.from_columns(N, src, dst)},
    )
    host = ex.run(max_iters=64)
    dev = ex.run(max_iters=64, on_device=True)
    assert dev.converged and dev.iterations == host.iterations
    assert (
        np.asarray(dev.state["tc"].present)
        == np.asarray(host.state["tc"].present)
    ).all()


@pytest.mark.parametrize("semi_naive", [False, True])
def test_connected_components_matches_numpy_oracle(semi_naive):
    src, dst = _edges(seed=1, m=40)
    s2, d2 = np.concatenate([src, dst]), np.concatenate([dst, src])
    ex = compile_program(
        connected_components_program(),
        {
            "edge": Relation.from_columns(N, s2, d2),
            "node": Relation.from_columns(
                N, np.arange(N), np.arange(N, dtype=np.float32)
            ),
        },
        semi_naive=semi_naive,
    )
    if semi_naive:
        # min is idempotent: C2 reads the delta frontier, and the rewrite
        # is recorded in the plan notes.
        assert "semi-naive(C2: cc -> Δcc)" in ex.plan.notes
    res = ex.run(max_iters=100)
    assert res.converged
    lab = np.arange(N, dtype=np.float32)
    adj = np.zeros((N, N), bool)
    adj[s2, d2] = True
    while True:
        new = lab.copy()
        for y, x in zip(*np.nonzero(adj)):
            new[x] = min(new[x], lab[y])
        if (new == lab).all():
            break
        lab = new
    got = np.asarray(res.state["cc"].values[1])
    present = np.asarray(res.state["cc"].present)
    assert present.all()
    assert (got == lab).all()


def test_same_generation_matches_numpy_oracle():
    rng = np.random.default_rng(4)
    par_p, par_c = rng.integers(0, N, 36), rng.integers(0, N, 36)
    ex = compile_program(
        same_generation_program(),
        {"parent": Relation.from_columns(N, par_p, par_c)},
    )
    res = ex.run(max_iters=100)
    assert res.converged
    par = np.zeros((N, N), bool)
    par[par_p, par_c] = True
    sg = (par.T @ par) > 0
    while True:
        new = sg | (par.T @ sg @ par)
        if (new == sg).all():
            break
        sg = new
    assert (np.asarray(res.state["sg"].present) == sg).all()


def test_multi_stratum_pipeline_matches_numpy_oracle():
    """PageRank fixpoint -> threshold over the *converged* ranks -> a second
    reachability fixpoint — the sequential multi-stratum execution neither
    listing front-end can express."""

    rng = np.random.default_rng(2)
    src = np.repeat(np.arange(N), 3)
    dst = rng.integers(0, N, 3 * N)
    deg = np.bincount(src, minlength=N).astype(np.float32)
    iters = 40

    # Oracle ranks first; put the threshold in the middle of the largest
    # gap so float-order differences cannot flip the hot set.
    adj = np.zeros((N, N), np.float32)
    adj[src, dst] = 1.0  # duplicate edges collapse on the grid, as in Datalog
    r = np.full(N, 1.0 / N, np.float32)
    for _ in range(iters):
        r = (0.85 * (adj.T @ (r / np.maximum(deg, 1.0)))
             + 0.15 / N).astype(np.float32)
    srt = np.sort(r)
    gaps = np.diff(srt)
    gi = int(np.argmax(gaps))
    tau = float((srt[gi] + srt[gi + 1]) / 2)
    assert gaps[gi] > 1e-4

    ex = compile_program(
        pagerank_threshold_program(tau=tau),
        {
            "edge": Relation.from_columns(N, src, dst),
            "node": Relation.from_columns(
                N, np.arange(N),
                np.full(N, 1.0 / N, np.float32),
                deg,
                np.full(N, 0.15 / N, np.float32),
            ),
        },
    )
    res = ex.run(max_iters=iters)
    assert len(res.phase_iterations) == 2
    assert res.phase_iterations[0] == iters  # PageRank runs its budget
    assert res.phase_iterations[1] < iters   # reach converges

    rank = np.asarray(res.state["rank"].values[1])
    assert np.abs(rank - r).max() < 1e-6

    hot = r > tau
    assert (np.asarray(res.state["hot"].present) == hot).all()

    reach = hot.copy()
    while True:
        new = reach | ((((adj > 0).T @ reach) > 0) & hot)
        if (new == reach).all():
            break
        reach = new
    assert (np.asarray(res.state["reach"].present) == reach).all()


def test_plan_records_phases_and_groupby_connectors():
    src, dst = _edges()
    deg = np.bincount(src, minlength=N).astype(np.float32)
    ex = compile_program(
        pagerank_threshold_program(),
        {
            "edge": Relation.from_columns(N, src, dst),
            "node": Relation.from_columns(
                N, np.arange(N), np.full(N, 1.0 / N, np.float32), deg,
                np.full(N, 0.15 / N, np.float32),
            ),
        },
    )
    assert "fixpoint-phases(rank -> reach)" in ex.plan.notes
    assert f"groupby(P2: sum via dense-reduce, {N * N} rows -> {N})" \
        in ex.plan.notes
    assert ex.plan.connectors["P2"] == "dense-reduce"


# ---------------------------------------------------------------------------
# Listings 1/2 through compile_program vs the specialized front-ends
# ---------------------------------------------------------------------------


def _pagerank_vp():
    return VertexProgram(
        init_vertex=lambda ids, vd: jnp.stack(
            [jnp.full((N,), 1.0 / N), vd], axis=1),
        message=lambda j, s, ed: s[:, 0] / jnp.maximum(s[:, 1], 1.0),
        apply=lambda j, s, inbox, got: (
            jnp.stack([0.15 / N + 0.85 * inbox, s[:, 1]], axis=1),
            jnp.ones(s.shape[0], jnp.bool_)),
        combine="sum",
    )


def _sssp_vp():
    inf = jnp.float32(1e9)
    return VertexProgram(
        init_vertex=lambda ids, vd: jnp.where(ids == 0, 0.0, inf),
        message=lambda j, s, ed: s + 1.0,
        apply=lambda j, s, inbox, got: (
            jnp.minimum(s, inbox), jnp.minimum(s, inbox) < s),
        combine="min",
    )


def _graph(seed=5):
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(N), 4).astype(np.int32)
    dst = rng.integers(0, N, 4 * N).astype(np.int32)
    outdeg = np.bincount(src, minlength=N).astype(np.float32)
    return Graph(N, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(outdeg))


@pytest.mark.parametrize("connector", CONNECTORS)
@pytest.mark.parametrize("make_vp,iters", [(_pagerank_vp, 12), (_sssp_vp, 40)])
def test_listing1_via_compile_program_matches_compile_pregel(
    connector, make_vp, iters
):
    vp, g = make_vp(), _graph()
    spec = compile_pregel(vp, g, force_connector=connector)
    gen = compile_program(
        vp.program(), {"data": g}, binding=vp, force_connector=connector
    )
    assert type(gen).__name__ == "PregelExecutable"
    assert gen.plan.notes == spec.plan.notes  # refactor leaves notes alone
    a = spec.run(max_iters=iters)
    b = gen.run(max_iters=iters)
    assert a.iterations == b.iterations
    err = float(jnp.max(jnp.abs(a.state[0] - b.state[0])))
    assert err <= 1e-8


@pytest.mark.parametrize("connector", CONNECTORS)
def test_listing1_semi_naive_via_compile_program(connector):
    vp, g = _sssp_vp(), _graph(seed=6)
    spec = compile_pregel(vp, g, force_connector=connector, semi_naive=True)
    gen = compile_program(
        vp.program(), {"data": g}, binding=vp, force_connector=connector,
        semi_naive=True,
    )
    assert gen.plan.notes == spec.plan.notes
    a = spec.run(max_iters=60)
    b = gen.run(max_iters=60)
    assert a.converged and b.converged
    err = float(jnp.max(jnp.abs(a.state[0] - b.state[0])))
    assert err <= 1e-8


def test_listing2_via_compile_program_matches_compile_imru():
    rng = np.random.default_rng(7)
    X = rng.normal(size=(256, 8)).astype(np.float32)
    w = rng.normal(size=8).astype(np.float32)
    y = X @ w
    task = IMRUTask(
        init_model=lambda: jnp.zeros(8, jnp.float32),
        map=lambda rec, m: (rec["x"] @ m - rec["y"]) @ rec["x"],
        update=lambda j, m, g: m - 1e-3 * g,
        tol=1e-9,
    )
    recs = {"x": jnp.asarray(X), "y": jnp.asarray(y)}
    spec = compile_imru(task, recs)
    gen = compile_program(
        task.program(), {"training_data": recs}, binding=task
    )
    assert type(gen).__name__ == "IMRUExecutable"
    assert gen.plan.notes == spec.plan.notes
    a = spec.run(max_iters=80)
    b = gen.run(max_iters=80)
    assert a.iterations == b.iterations
    err = float(jnp.max(jnp.abs(a.state - b.state)))
    assert err <= 1e-8


# ---------------------------------------------------------------------------
# Fail-closed surfaces
# ---------------------------------------------------------------------------


def test_listing_program_without_binding_is_rejected():
    vp = _pagerank_vp()
    with pytest.raises(ExecutorError, match="binding"):
        compile_program(vp.program(), {"data": _graph()})


def test_missing_edb_relation_is_rejected():
    with pytest.raises(ExecutorError, match="edge"):
        compile_program(transitive_closure_program(), {}, domain=N)


def test_unregistered_aggregate_is_rejected():
    from repro.core.datalog import Aggregate
    import dataclasses

    prog = connected_components_program()
    bogus = Aggregate("mystery", zero=lambda: 0.0, combine=min)
    rules = tuple(
        dataclasses.replace(
            r,
            head=dataclasses.replace(
                r.head,
                args=tuple(
                    dataclasses.replace(a, agg="mystery")
                    if hasattr(a, "agg") else a
                    for a in r.head.args
                ),
            ),
        )
        for r in prog.rules
    )
    prog = dataclasses.replace(
        prog, rules=rules, aggregates={"mystery": bogus}
    )
    src, dst = _edges()
    with pytest.raises(ExecutorError, match="monoid"):
        ex = compile_program(
            prog,
            {
                "edge": Relation.from_columns(N, src, dst),
                "node": Relation.from_columns(
                    N, np.arange(N), np.arange(N, dtype=np.float32)
                ),
            },
        )
        ex.run(max_iters=2)


def test_relation_from_columns_splits_keys_and_values():
    rel = Relation.from_columns(
        8, np.array([1, 3]), np.array([0.5, 2.5], np.float32)
    )
    assert rel.key_positions == (0,)
    assert rel.arity == 2
    assert rel.count() == 2
    assert float(rel.values[1][3]) == 2.5
    assert rel.tuples().tolist() == [[1], [3]]
