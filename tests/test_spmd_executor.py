"""Unified-executor SPMD conformance (8 virtual devices, subprocess).

See tests/spmd_executor_program.py for the properties defended; this
launcher asserts on its RESULTS_JSON (shared _spmd_subprocess runner, so the
main pytest process keeps seeing 1 device)."""

from tests._spmd_subprocess import run_spmd_program


def test_unified_executor_spmd_conformance():
    results = run_spmd_program("spmd_executor_program.py")

    for name, err in results["generic_errs"].items():
        assert err <= 1e-8, (name, err)

    # Both layouts run the same fixpoint lengths.
    assert results["tc_iters"][0] == results["tc_iters"][1]
    assert len(results["pipeline_phases"]) == 2

    for name, err in results["listing1_errs"].items():
        if name.endswith("_notes_equal"):
            assert err is True, name
        else:
            assert err <= 1e-8, (name, err)

    assert results["listing2_err"] <= 1e-8
    assert results["listing2_notes_equal"] is True
