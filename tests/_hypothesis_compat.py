"""Tiny fallback for ``hypothesis`` so the suite collects everywhere.

When ``hypothesis`` is installed the test modules import the real thing; when
it is absent (minimal CI images, the CPU container) they fall back to this
shim, which replays each ``@given`` test over a small deterministic sample of
the strategy space instead of skipping the property tests outright.  Only the
strategy surface the suite actually uses is implemented (``st.integers``,
``st.sampled_from``); anything else should be added here when a test needs
it, or the test should ``pytest.importorskip("hypothesis")``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Sequence

import numpy as np

__all__ = ["given", "settings", "strategies", "HealthCheck"]

# Deterministic example count for the fallback replay (the real hypothesis
# default is 100 shrinking examples; a handful is enough for smoke coverage).
_FALLBACK_EXAMPLES = 5


@dataclass(frozen=True)
class _Strategy:
    """A sampleable value source: ``sample(rng)`` draws one example."""

    sample: Callable[[np.random.Generator], Any]
    edge_cases: tuple = ()


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            sample=lambda rng: int(rng.integers(min_value, max_value + 1)),
            edge_cases=(min_value, max_value),
        )

    @staticmethod
    def sampled_from(options: Sequence[Any]) -> _Strategy:
        options = list(options)
        return _Strategy(
            sample=lambda rng: options[int(rng.integers(len(options)))],
            edge_cases=(options[0], options[-1]),
        )

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(
            sample=lambda rng: bool(rng.integers(2)),
            edge_cases=(False, True),
        )


strategies = _Strategies()


class HealthCheck:
    """Placeholder namespace mirroring ``hypothesis.HealthCheck``."""

    too_slow = data_too_large = filter_too_much = None
    all = staticmethod(lambda: ())


def settings(*_args, **_kwargs):
    """No-op decorator: the shim has no deadlines or example budgets."""

    def deco(fn):
        return fn

    return deco


def given(**strategy_kwargs):
    """Replay the test over deterministic draws from each strategy.

    The first example combines every strategy's first edge case (min values),
    the second combines the last (max values), and the rest are seeded random
    draws — a fixed, reproducible sample standing in for hypothesis search.
    """

    names = list(strategy_kwargs)
    strats = [strategy_kwargs[n] for n in names]

    def deco(fn):
        # No functools.wraps: copying __wrapped__ would make pytest resolve
        # the original signature and demand fixtures for the strategy params.
        def wrapper(*args, **kwargs):
            examples = []
            for pick in (0, -1):
                examples.append(
                    {
                        n: s.edge_cases[pick]
                        for n, s in zip(names, strats)
                        if s.edge_cases
                    }
                )
            rng = np.random.default_rng(0)
            for _ in range(_FALLBACK_EXAMPLES):
                examples.append({n: s.sample(rng) for n, s in zip(names, strats)})
            for ex in examples:
                fn(*args, **{**kwargs, **ex})

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
