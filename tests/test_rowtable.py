"""Row-table storage differential conformance.

Properties defended:

1. **Forced row-table == dense-grid** — every shipped generic program
   (transitive closure, connected components naive AND semi-naive,
   same-generation, negated-reach, the multi-stratum PageRank pipeline)
   compiled with ``storage="row-table"`` matches the dense engine <= 1e-8
   on the host driver and the on-device ``lax.while_loop`` driver.

2. **Planner-selected row tables scale past the dense wall** — generic TC
   over a 65536-vertex sparse edge set (where the dense ``n^2`` grid is a
   4 GiB bool array) completes on planner-selected row tables and matches
   a NumPy closure oracle *exactly*.

3. **AntiJoin is exact set-difference** — ``difference_row_codes`` matches
   Python set difference on key codes spanning the full uint32 range,
   where no dense mask could even be materialized.

4. **Lossless overflow fallback** — a row run that overflows its static
   capacity transparently re-runs on dense grids (``storage_fallback`` set)
   and produces the identical fixpoint; a ``RowRelation`` EDB (no dense
   grid to fall back to) raises instead of silently truncating.

5. **Input hardening** — ``Relation.from_columns`` / ``RowRelation.from_columns``
   deduplicate rows (keep-last, Datalog update semantics) and fail loudly
   on out-of-domain or negative vertex ids instead of index-wrapping.

The 8-virtual-device mesh conformance lives in
``tests/test_spmd_rowtable.py`` (subprocess launcher).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.executor import (
    ExecutorError,
    Relation,
    RowRelation,
    compile_program,
)
from repro.core.listings import (
    connected_components_program,
    negated_reach_program,
    pagerank_threshold_program,
    same_generation_program,
    transitive_closure_program,
)
from repro.core.physical import difference_row_codes

N = 32


def _edges(seed=0, m=48, n=N):
    rng = np.random.default_rng(seed)
    return rng.integers(0, n, m), rng.integers(0, n, m)


def _grid(rel):
    """Dense bool/value grids from either relation representation."""
    if isinstance(rel, RowRelation):
        rel = rel.to_dense()
    return np.asarray(rel.present), {
        k: np.asarray(v) for k, v in rel.values.items()
    }


def _assert_state_close(dense_res, row_res, preds, atol=1e-8):
    for p in preds:
        dp, dv = _grid(dense_res.state[p])
        rp, rv = _grid(row_res.state[p])
        assert np.array_equal(dp, rp), p
        for k in dv:
            # value columns only compared where present
            assert np.abs(np.where(dp, dv[k] - rv[k], 0.0)).max() <= atol, \
                (p, k)


# ---------------------------------------------------------------------------
# 1. Forced row-table vs dense, all programs, host + device drivers
# ---------------------------------------------------------------------------


def _tc_setup():
    src, dst = _edges()
    rels = {"edge": Relation.from_columns(N, src, dst)}
    return transitive_closure_program(), rels, ("tc",), {}


def _cc_setup(semi_naive):
    src, dst = _edges(seed=1, m=40)
    s2, d2 = np.concatenate([src, dst]), np.concatenate([dst, src])
    rels = {
        "edge": Relation.from_columns(N, s2, d2),
        "node": Relation.from_columns(
            N, np.arange(N), np.arange(N, dtype=np.float32)),
    }
    return (connected_components_program(), rels, ("cc",),
            {"semi_naive": semi_naive})


def _sg_setup():
    pp, pc = _edges(seed=4, m=36)
    rels = {"parent": Relation.from_columns(N, pp, pc)}
    return same_generation_program(), rels, ("sg",), {}


def _nr_setup():
    n = 64
    src, dst = _edges(seed=0, m=96, n=n)
    rels = {
        "edge": Relation.from_columns(n, src, dst),
        "source": Relation.from_columns(
            n, np.arange(8), np.array([1, 0, 1, 1, 0, 1, 0, 1], np.float32)),
        "blocked": Relation.from_columns(n, np.array([3, 9, 27])),
        "node": Relation.from_columns(
            n, np.arange(n), (np.arange(n) % 5).astype(np.float32)),
    }
    return negated_reach_program(), rels, ("reach",), {}


def _pr_setup():
    # Larger domain than the boolean programs: ranks scale as 1/n, so at
    # n=256 a few ULPs of f32 summation-order drift between the two
    # compiled programs sit near 1e-9 — comfortably inside the 1e-8 bar
    # the boolean predicates meet exactly.
    n = 256
    rng = np.random.default_rng(2)
    src = np.repeat(np.arange(n), 3)
    dst = rng.integers(0, n, 3 * n)
    deg = np.bincount(src, minlength=n).astype(np.float32)
    rels = {
        "edge": Relation.from_columns(n, src, dst),
        "node": Relation.from_columns(
            n, np.arange(n), np.full(n, 1.0 / n, np.float32), deg,
            np.full(n, 0.15 / n, np.float32)),
    }
    return (pagerank_threshold_program(tau=1.5 / n), rels,
            ("rank", "hot", "reach"), {"iters": 60})


_PROGRAMS = {
    "tc": _tc_setup,
    "cc-naive": lambda: _cc_setup(False),
    "cc-semi-naive": lambda: _cc_setup(True),
    "sg": _sg_setup,
    "negated-reach": _nr_setup,
    "pagerank-pipeline": _pr_setup,
}


@pytest.mark.parametrize("name", sorted(_PROGRAMS))
@pytest.mark.parametrize("on_device", [False, True])
def test_forced_row_table_matches_dense(name, on_device):
    program, rels, preds, kw = _PROGRAMS[name]()
    iters = kw.pop("iters", 100)
    dense = compile_program(program, dict(rels), **kw).run(
        max_iters=iters, on_device=on_device)
    row_ex = compile_program(
        program, dict(rels), storage="row-table", **kw)
    assert all(s == "row-table" for s in row_ex.storage.values())
    row = row_ex.run(max_iters=iters, on_device=on_device)
    assert row.converged == dense.converged
    assert not row.storage_fallback
    for p in preds:
        assert isinstance(row.state[p], RowRelation)
    _assert_state_close(dense, row, preds)


# ---------------------------------------------------------------------------
# 2. 64k-vertex sparse TC on planner-selected row tables (exact)
# ---------------------------------------------------------------------------


def test_tc_64k_sparse_matches_closure_oracle_exactly():
    n, block = 65536, 8
    starts = np.arange(0, n, block)
    src = np.concatenate(
        [np.arange(s, s + block - 1) for s in starts])
    dst = src + 1
    edge = RowRelation.from_columns(n, src, dst)

    ex = compile_program(transitive_closure_program(), {"edge": edge})
    # The planner must have picked row tables on its own: the dense n^2
    # grid would be 4 GiB of bool.
    assert ex.storage == {"edge": "row-table", "tc": "row-table"}

    res = ex.run(max_iters=16)
    assert res.converged and not res.storage_fallback
    tc = res.state["tc"]
    assert isinstance(tc, RowRelation)

    oracle = set()
    for s in range(0, n, block):
        for i in range(s, s + block):
            for j in range(i + 1, s + block):
                oracle.add((i, j))
    assert set(map(tuple, tc.rows.tolist())) == oracle


# ---------------------------------------------------------------------------
# 3. AntiJoin == exact set-difference (no dense mask possible)
# ---------------------------------------------------------------------------


def test_difference_row_codes_is_exact_set_difference():
    rng = np.random.default_rng(7)
    # Codes across the whole uint32 range — a dense mask over this key
    # space would be 4 Gi entries, so only true set-difference can work.
    left = rng.integers(0, 2**32, 512, dtype=np.uint32)
    right = rng.integers(0, 2**32, 256, dtype=np.uint32)
    right[:128] = left[:128]  # guarantee overlap
    lv = rng.random(512) < 0.9
    rv = rng.random(256) < 0.9

    keep = np.asarray(difference_row_codes(
        jnp.asarray(left), jnp.asarray(lv),
        jnp.asarray(right), jnp.asarray(rv)))

    rset = set(right[rv].tolist())
    expect = lv & np.array([c not in rset for c in left.tolist()])
    assert np.array_equal(keep, expect)


def test_negated_reach_row_antijoin_excludes_blocked():
    program, rels, _, _ = _nr_setup()
    ex = compile_program(program, dict(rels), storage="row-table")
    res = ex.run(max_iters=64)
    reach = res.state["reach"]
    assert isinstance(reach, RowRelation)
    got = set(reach.rows[:, 0].tolist())
    # Node 3 is blocked AND a source: N1 (no negation) admits it, but N2's
    # AntiJoin must never extend reach INTO a blocked node, so the other
    # blocked nodes stay out no matter how many edges point at them.
    assert got & {9, 27} == set()
    # The set-difference is not lossy either: unblocked neighbours of
    # reached nodes with weight < 3 are present (dense engine agrees, per
    # the differential test above — here we pin one hand-checked property).
    assert 3 in got  # source survives stratum N1


# ---------------------------------------------------------------------------
# 4. Capacity overflow: lossless dense fallback
# ---------------------------------------------------------------------------


def test_row_cap_overflow_falls_back_to_dense_losslessly():
    src, dst = _edges()
    edge = Relation.from_columns(N, src, dst)
    dense = compile_program(
        transitive_closure_program(), {"edge": edge}).run(max_iters=64)

    ex = compile_program(
        transitive_closure_program(), {"edge": edge},
        storage="row-table", row_cap=64)
    res = ex.run(max_iters=64)
    assert res.storage_fallback
    assert isinstance(res.state["tc"], Relation)
    assert np.array_equal(
        np.asarray(res.state["tc"].present),
        np.asarray(dense.state["tc"].present))


def test_row_cap_overflow_with_row_edb_raises():
    src, dst = _edges()
    edge = RowRelation.from_columns(N, src, dst)
    ex = compile_program(
        transitive_closure_program(), {"edge": edge},
        storage="row-table", row_cap=64)
    with pytest.raises(ExecutorError, match="row-table capacity overflow"):
        ex.run(max_iters=64)


def test_row_edb_rejects_forced_dense():
    src, dst = _edges()
    edge = RowRelation.from_columns(N, src, dst)
    with pytest.raises(ExecutorError, match="dense"):
        compile_program(transitive_closure_program(), {"edge": edge},
                        storage="dense-grid")


# ---------------------------------------------------------------------------
# 5. from_columns hardening (both representations)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls", [Relation, RowRelation])
@pytest.mark.parametrize("bad", [99, -1])
def test_from_columns_rejects_out_of_domain_ids(cls, bad):
    with pytest.raises(ExecutorError, match="outside the domain"):
        cls.from_columns(8, np.array([0, bad]), np.array([1, 2]))


def test_from_columns_deduplicates_keep_last():
    keys = np.array([1, 1, 2])
    vals = np.array([10.0, 20.0, 30.0], np.float32)

    dense = Relation.from_columns(8, keys, np.array([3, 3, 4]), vals)
    assert np.asarray(dense.present).sum() == 2
    assert np.asarray(dense.values[2])[1, 3] == 20.0

    row = RowRelation.from_columns(8, keys, np.array([3, 3, 4]), vals)
    assert row.count() == 2
    assert row.rows.tolist() == [[1, 3], [2, 4]]
    assert row.values[2].tolist() == [20.0, 30.0]


def test_row_relation_round_trips_to_dense():
    src, dst = _edges(seed=9, m=20)
    w = np.arange(20, dtype=np.float32)
    row = RowRelation.from_columns(N, src, dst, w)
    dense = Relation.from_columns(N, src, dst, w)
    assert np.array_equal(
        np.asarray(row.to_dense().present), np.asarray(dense.present))
    assert np.array_equal(
        np.asarray(row.to_dense().values[2]), np.asarray(dense.values[2]))
