"""Fault-tolerance tests: driver hygiene, failure injection, durable
checkpoint/restore of in-flight fixpoints (incl. the multi-stratum phase
cursor), elastic replanning, straggler fallback, and the monoid-generalized
bounded-staleness aggregate."""

import os
import time

import numpy as np
import pytest
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal images: deterministic fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint import CheckpointStore, save_pytree
from repro.core.executor import (
    ExecutorError,
    Relation,
    compile_program,
)
from repro.core.fixpoint import DriverConfig, HostFixpointDriver
from repro.core.imru import IMRUTask, compile_imru
from repro.core.listings import (
    pagerank_threshold_program,
    transitive_closure_program,
)
from repro.core.monoid import MonoidError, get_monoid, registered_monoids
from repro.core.pregel import Graph, VertexProgram, compile_pregel
from repro.ft import ElasticPlanner, FailureInjector
from repro.ft.elastic import stale_aggregate

RNG = np.random.default_rng(7)
N = 24


# ---------------------------------------------------------------------------
# Driver hygiene (regressions for the shared-default / class-attribute bugs)
# ---------------------------------------------------------------------------


def _noop_driver(**kw):
    return HostFixpointDriver(
        step=lambda s, j: s, converged=lambda a, b: True, **kw
    )


def test_driver_config_default_is_fresh_per_instance():
    d1 = _noop_driver()
    d1.config.max_iters = 7
    d1.config.checkpoint_every = 99
    d2 = _noop_driver()
    assert d2.config.max_iters == 1000
    assert d2.config.checkpoint_every == 0


def test_driver_fail_at_is_instance_state():
    d1 = _noop_driver()
    d1.fail_at = 3
    d1._failed_once = True
    d2 = _noop_driver()
    assert d2.fail_at is None and d2._failed_once is False


# ---------------------------------------------------------------------------
# Failure injection at the step boundary
# ---------------------------------------------------------------------------


def test_injector_crash_without_restore_raises():
    inj = FailureInjector(crashes=[2])
    driver = HostFixpointDriver(
        step=lambda s, j: s + 1.0,
        converged=lambda a, b: False,
        config=DriverConfig(max_iters=5),
        injector=inj,
    )
    with pytest.raises(RuntimeError, match="injected device failure"):
        driver.run(jnp.zeros(2))
    assert inj.fired and inj.fired[0].kind == "crash"


def test_injector_straggle_is_detected_and_hook_fires():
    seen = []
    inj = FailureInjector(straggles=[(6, 0.3)])
    driver = HostFixpointDriver(
        step=lambda s, j: s + 1.0,
        converged=lambda a, b: False,
        config=DriverConfig(max_iters=10, straggler_factor=3.0),
        injector=inj,
        on_straggler=lambda j, dt: seen.append(j),
    )
    res = driver.run(jnp.zeros(2))
    assert res.straggler_events >= 1
    assert 6 in seen
    assert any(e.kind == "straggle" for e in inj.fired)


# ---------------------------------------------------------------------------
# Checkpoint store: error surfacing + structure mismatch
# ---------------------------------------------------------------------------


def test_store_background_failure_surfaces_on_wait_and_next_save(tmp_path):
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("x")
    store = CheckpointStore(str(blocker))
    store.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(OSError):
        store.wait()
    # the error is consumed once; a save into the same broken dir re-fails
    store.save(2, {"a": jnp.zeros(2)})
    with pytest.raises(OSError):
        store.save(3, {"a": jnp.zeros(2)})


def test_store_gc_drops_stale_lineage_from_reused_directory(tmp_path):
    """A fresh run reusing a checkpoint dir restarts the step counter; the
    previous lineage's higher-numbered steps must not starve the live run's
    checkpoints out of the retention window."""

    d = str(tmp_path)
    tree = {"a": jnp.zeros(2)}
    first = CheckpointStore(d, keep=3)
    for s in (16, 20, 24):
        first.save(s, tree)
    first.wait()
    second = CheckpointStore(d, keep=3)
    for s in (0, 4, 8):
        second.save(s, tree)
    second.wait()
    _, step, _ = second.restore(like=tree)
    assert step == 8
    left = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert left == ["step_00000000", "step_00000004", "step_00000008"]


def test_restore_treedef_mismatch_raises_clear_error(tmp_path):
    save_pytree(str(tmp_path), 1, {"a": np.zeros(3, np.float32)})
    store = CheckpointStore(str(tmp_path))
    with pytest.raises(ValueError, match="tree structure"):
        store.restore(
            like={"a": jnp.zeros(3), "b": jnp.zeros(2)}
        )


# ---------------------------------------------------------------------------
# Elastic replanning edge cases
# ---------------------------------------------------------------------------


def test_elastic_replan_single_replica_boundary():
    ep = ElasticPlanner(model_axis=16)
    mesh, stranded = ep.replan(16)
    assert mesh.n_devices == 16 and stranded == 0
    assert mesh.size("data") == 1 and mesh.size("model") == 16
    with pytest.raises(RuntimeError, match="cannot host one model replica"):
        ep.replan(15)
    with pytest.raises(RuntimeError):
        ep.replan(0)


def test_elastic_replan_stranded_accounting():
    ep = ElasticPlanner(model_axis=16)
    mesh, stranded = ep.replan(67)
    assert mesh.n_devices == 64 and stranded == 3
    assert mesh.size("data") == 4


def test_elastic_replan_multi_pod_split():
    ep = ElasticPlanner(model_axis=16)
    mesh, stranded = ep.replan(64, multi_pod=True)
    assert mesh.size("pod") == 2 and mesh.size("data") == 2
    assert mesh.n_devices == 64 and stranded == 0
    # an odd replica count cannot split into two pods: falls back flat
    mesh, stranded = ep.replan(48, multi_pod=True)
    assert mesh.size("pod") == 1 and mesh.size("data") == 3


# ---------------------------------------------------------------------------
# Bounded-staleness aggregation over the monoid registry
# ---------------------------------------------------------------------------


def _slabs(m, n_shards, seed):
    rng = np.random.default_rng(seed)
    if m.structured:
        return jnp.asarray(rng.normal(size=(n_shards, 5, 2)), jnp.float32)
    return jnp.asarray(rng.normal(size=(n_shards, 5)), jnp.float32)


def _fold(m, slabs):
    out = slabs[0]
    for i in range(1, slabs.shape[0]):
        out = m.combine(out, slabs[i])
    return out


def _eligible(name):
    m = get_monoid(name)
    return name == "sum" or m.idempotent or bool(m.is_delta_safe)


@pytest.mark.parametrize("name", registered_monoids())
def test_stale_aggregate_eligibility_fails_closed(name):
    m = get_monoid(name)
    partials = _slabs(m, 4, 0)
    carry = m.identity_like(partials[0])
    if _eligible(name):
        out, late = stale_aggregate(
            partials, jnp.ones(4, bool), carry, monoid=name
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(_fold(m, partials)),
            rtol=1e-5, atol=1e-6,
        )
    else:
        with pytest.raises(MonoidError, match="failing closed"):
            stale_aggregate(partials, jnp.ones(4, bool), carry, monoid=name)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(2, 5))
def test_stale_aggregate_never_drops_contributions(seed, steps):
    """Fold of the emitted aggregates + the final carry == full reduce over
    every partial ever produced, under random arrival masks — for every
    eligible registered monoid."""

    rng = np.random.default_rng(seed)
    for name in registered_monoids():
        if not _eligible(name):
            continue
        m = get_monoid(name)
        n_shards = 4
        carry = m.identity_like(_slabs(m, n_shards, 0)[0])
        outs, all_partials = [], []
        for t in range(steps):
            p = _slabs(m, n_shards, rng.integers(0, 2**31))
            mask = jnp.asarray(
                rng.integers(0, 2, n_shards).astype(bool)
            )
            out, carry = stale_aggregate(p, mask, carry, monoid=name)
            outs.append(out)
            all_partials.append(p)
        if name == "sum":
            total = sum(np.asarray(o, np.float64) for o in outs) \
                + np.asarray(carry, np.float64)
            want = np.asarray(
                jnp.concatenate(all_partials, axis=0), np.float64
            ).sum(0)
            np.testing.assert_allclose(total, want, rtol=1e-4, atol=1e-5)
        else:
            total = outs[0]
            for o in outs[1:]:
                total = m.combine(total, o)
            total = m.combine(total, carry)
            want = _fold(m, jnp.concatenate(all_partials, axis=0))
            np.testing.assert_allclose(
                np.asarray(total), np.asarray(want), rtol=1e-5, atol=1e-6
            )


# ---------------------------------------------------------------------------
# Generic executor: durable checkpoint/restore + phase cursor
# ---------------------------------------------------------------------------


def _tc_fixture():
    rng = np.random.default_rng(0)
    src, dst = rng.integers(0, N, 40), rng.integers(0, N, 40)
    return compile_program(
        transitive_closure_program(),
        {"edge": Relation.from_columns(N, src, dst)},
    )


def _pipeline_fixture():
    rng = np.random.default_rng(0)
    src, dst = rng.integers(0, N, 40), rng.integers(0, N, 40)
    deg = np.maximum(np.bincount(src, minlength=N), 1).astype(np.float32)
    rels = {
        "edge": Relation.from_columns(N, src, dst),
        "node": Relation.from_columns(
            N, np.arange(N), np.full(N, 1.0 / N, np.float32), deg,
            np.full(N, 0.15 / N, np.float32),
        ),
    }
    return lambda: compile_program(pagerank_threshold_program(tau=0.04), rels)


def _assert_states_equal(a, b, atol=1e-8):
    assert set(a) == set(b)
    for k in a:
        assert (np.asarray(a[k].present) == np.asarray(b[k].present)).all(), k
        for p in a[k].values:
            np.testing.assert_allclose(
                np.asarray(a[k].values[p]), np.asarray(b[k].values[p]),
                atol=atol,
            )


def test_executor_crash_restore_matches_uninterrupted(tmp_path):
    ex = _tc_fixture()
    clean = ex.run(max_iters=64)
    res = ex.run(
        max_iters=64, checkpoint_dir=str(tmp_path), checkpoint_every=2,
        injector=FailureInjector(crashes=[3]),
    )
    assert res.restarts == 1 and res.converged
    _assert_states_equal(clean.state, res.state)


def test_executor_ft_requires_host_driver(tmp_path):
    ex = _tc_fixture()
    with pytest.raises(ExecutorError, match="host"):
        ex.run(max_iters=8, on_device=True, checkpoint_dir=str(tmp_path))
    with pytest.raises(ExecutorError, match="resume"):
        ex.run(max_iters=8, resume=True)


def test_executor_phase_cursor_resume_skips_completed_phase(tmp_path):
    """Kill the pipeline inside the *reach* phase; the resumed run continues
    in that phase without re-running the 20-iteration *rank* phase — proven
    by arming a crash at a rank-phase global step that never fires."""

    make = _pipeline_fixture()
    clean = make().run(max_iters=20)
    assert len(clean.phase_iterations) == 2
    rank_iters = clean.phase_iterations[0]
    d = str(tmp_path)
    with pytest.raises(RuntimeError, match="injected device failure"):
        # crash at the first reach-phase step, with no restart budget
        make().run(
            max_iters=20, checkpoint_dir=d, checkpoint_every=4,
            injector=FailureInjector(crashes=[rank_iters]), max_restarts=0,
        )
    trap = FailureInjector(crashes=[2])  # global step 2 lives in rank
    res = make().run(
        max_iters=20, checkpoint_dir=d, checkpoint_every=4, resume=True,
        injector=trap,
    )
    assert res.restarts == 0          # the rank-phase trap never fired
    assert trap.fired == []
    assert res.phase_iterations == clean.phase_iterations
    _assert_states_equal(clean.state, res.state)


def test_executor_mid_phase_resume_matches_uninterrupted(tmp_path):
    ex = _tc_fixture()
    clean = ex.run(max_iters=64)
    d = str(tmp_path)
    with pytest.raises(RuntimeError):
        ex.run(
            max_iters=64, checkpoint_dir=d, checkpoint_every=2,
            injector=FailureInjector(crashes=[3, 4]), max_restarts=1,
        )
    res = _tc_fixture().run(max_iters=64, checkpoint_dir=d, resume=True)
    assert res.converged
    # the resumed run reports only its own iterations, but the phase cursor
    # accounts for the replayed prefix
    assert res.phase_iterations == clean.phase_iterations
    _assert_states_equal(clean.state, res.state)


def test_executor_remesh_records_note_and_events():
    ex = _tc_fixture()
    clean = ex.run(max_iters=64)
    ex2 = ex.remesh(None)
    assert any(n.startswith("remesh(1->1") for n in ex2.plan.notes)
    res = ex2.run(max_iters=64)
    assert res.remesh_events == ex2.remesh_events
    assert len(res.remesh_events) == 1
    _assert_states_equal(clean.state, res.state)


# ---------------------------------------------------------------------------
# Pregel executable: checkpoint/restore knobs
# ---------------------------------------------------------------------------


def _pagerank_ex():
    n = 48
    rng = np.random.default_rng(1)
    src, dst = [], []
    for v in range(n):
        for _ in range(int(rng.integers(1, 4))):
            src.append(v)
            dst.append(int(rng.integers(0, n)))
        src.append(int(rng.integers(0, n)))
        dst.append(v)
    src = np.array(src, np.int32)
    dst = np.array(dst, np.int32)
    outdeg = np.bincount(src, minlength=n).astype(np.float32)
    g = Graph(n, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(outdeg))
    vp = VertexProgram(
        init_vertex=lambda ids, vd: jnp.stack(
            [jnp.full((n,), 1.0 / n), vd], axis=1
        ),
        message=lambda j, s, ed: s[:, 0] / jnp.maximum(s[:, 1], 1.0),
        apply=lambda j, s, inbox, got: (
            jnp.stack([0.15 / n + 0.85 * inbox, s[:, 1]], axis=1),
            jnp.ones(s.shape[0], jnp.bool_),
        ),
        combine="sum",
    )
    return compile_pregel(vp, g)


def test_pregel_crash_restore_and_resume(tmp_path):
    ex = _pagerank_ex()
    clean = ex.run(max_iters=25, on_device=False)
    d = str(tmp_path)
    res = ex.run(
        max_iters=25, checkpoint_dir=d, checkpoint_every=4,
        injector=FailureInjector(crashes=[9]),
    )
    assert res.restarts == 1
    np.testing.assert_allclose(
        np.asarray(res.state[0]), np.asarray(clean.state[0]), atol=1e-8
    )
    with pytest.raises(RuntimeError):
        ex.run(
            max_iters=25, checkpoint_dir=d, checkpoint_every=4,
            injector=FailureInjector(crashes=[10, 11]), max_restarts=1,
        )
    res2 = ex.run(max_iters=25, checkpoint_dir=d, resume=True)
    np.testing.assert_allclose(
        np.asarray(res2.state[0]), np.asarray(clean.state[0]), atol=1e-8
    )


def test_pregel_compile_time_injector_rides_the_bundle(tmp_path):
    ex = _pagerank_ex()
    clean = ex.run(max_iters=25, on_device=False)
    # injector threaded through compile_pregel -> build_pregel_steps
    from repro.core.executor import build_pregel_steps

    inj = FailureInjector(crashes=[5])
    bundle = build_pregel_steps(ex.prog, ex.graph, ex.plan, None,
                                injector=inj)
    assert bundle.injector is inj
    ex.injector = inj
    res = ex.run(
        max_iters=25, checkpoint_dir=str(tmp_path), checkpoint_every=2
    )
    assert res.restarts == 1 and inj.fired
    np.testing.assert_allclose(
        np.asarray(res.state[0]), np.asarray(clean.state[0]), atol=1e-8
    )


def test_pregel_remesh_records_note():
    ex = _pagerank_ex()
    ex2 = ex.remesh(None)
    assert any(n.startswith("remesh(1->1") for n in ex2.plan.notes)
    assert ex2.remesh_events and "remesh(1->1" in ex2.remesh_events[0]
    res = ex2.run(max_iters=25, on_device=False)
    assert res.remesh_events == ex2.remesh_events


# ---------------------------------------------------------------------------
# IMRU: straggler -> k-ary aggregation-tree fallback
# ---------------------------------------------------------------------------


def _bgd():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(128, 4)).astype(np.float32)
    w = rng.normal(size=4).astype(np.float32)
    y = X @ w
    task = IMRUTask(
        init_model=lambda: jnp.zeros(4, jnp.float32),
        map=lambda rec, m: ((rec["x"] @ m - rec["y"]) @ rec["x"]),
        update=lambda j, m, g: m - 1e-4 * g,
        tol=1e-7,
    )
    return task, {"x": jnp.asarray(X), "y": jnp.asarray(y)}


def test_imru_straggler_triggers_kary_fallback():
    task, records = _bgd()
    clean = compile_imru(task, records).run(max_iters=60, on_device=False)
    ex = compile_imru(task, records)
    res = ex.run(
        max_iters=60, on_device=False,
        injector=FailureInjector(straggles=[(8, 0.25)]),
    )
    assert res.straggler_events >= 1
    assert ex.straggler_fallbacks
    assert ex.plan.reduce.kind == "kary_tree"
    assert any("straggler-fallback(kary_tree" in n for n in ex.plan.notes)
    np.testing.assert_allclose(
        np.asarray(res.state), np.asarray(clean.state), rtol=1e-5
    )


def test_imru_checkpoint_resume(tmp_path):
    task, records = _bgd()
    ex = compile_imru(task, records)
    clean = ex.run(max_iters=60, on_device=False)
    d = str(tmp_path)
    with pytest.raises(RuntimeError):
        ex.run(
            max_iters=60, checkpoint_dir=d, checkpoint_every=10,
            injector=FailureInjector(crashes=[25, 26]), max_restarts=1,
            straggler_fallback=False,
        )
    res = ex.run(max_iters=60, checkpoint_dir=d, resume=True,
                 straggler_fallback=False)
    np.testing.assert_allclose(
        np.asarray(res.state), np.asarray(clean.state), rtol=1e-5
    )
