"""Sharded generalized-aggregate differential conformance (subprocess:
needs 8 fake devices while the main pytest process must keep seeing 1 —
same contract as test_spmd.py).

The subprocess (spmd_monoid_program.py) runs the four generalized
aggregates — argmin / topk / mean / logsumexp — through sharded dense AND
sharded sparse (delta-frontier) execution across all three Fig.-9
connectors, in float64, and compares fixpoints and pinned-frontier
supersteps against independent NumPy oracles; these tests assert on its
JSON report with the 1e-8 acceptance bar.
"""

import pytest

from _spmd_subprocess import run_spmd_program

WORKLOADS = ("argmin_sssp", "topk_prop", "mean_labelprop",
             "logsumexp_diffusion")
CONNECTORS = ("dense_psum", "merging", "hash_sort")


@pytest.fixture(scope="module")
def monoid_results():
    return run_spmd_program("spmd_monoid_program.py")


def test_sharded_fixpoints_match_numpy_oracle(monoid_results):
    errs = monoid_results["fixpoint_errs"]
    for name in WORKLOADS:
        for conn in CONNECTORS:
            for path in ("dense", "sparse"):
                key = f"{name}/{conn}/{path}"
                assert key in errs
                assert errs[key] <= 1e-8, (key, errs[key])


def test_sharded_supersteps_match_numpy_oracle(monoid_results):
    errs = monoid_results["superstep_errs"]
    for name in WORKLOADS:
        for conn in CONNECTORS:
            for path in ("dense", "sparse"):
                key = f"{name}/{conn}/{path}"
                assert key in errs
                assert errs[key] <= 1e-8, (key, errs[key])


def test_collapsing_monoid_workloads_go_sparse_in_lockstep(monoid_results):
    engaged = monoid_results["sparse_engaged"]
    for conn in CONNECTORS:
        # Collapsing frontiers (argmin SSSP, topk saturation) must actually
        # exercise the compacted path...
        assert engaged[f"argmin_sssp/{conn}"], conn
        assert engaged[f"topk_prop/{conn}"], conn
        # ...while always-active workloads stay dense in SPMD lockstep.
        assert not engaged[f"mean_labelprop/{conn}"], conn
        assert not engaged[f"logsumexp_diffusion/{conn}"], conn


def test_convergence_verdicts_agree_with_oracle(monoid_results):
    assert all(monoid_results["convergence_agrees"].values()), \
        monoid_results["convergence_agrees"]
