"""The online serving layer: plan cache, EDB cache, query batching.

Four properties defended:

1. **Plan-cache keying** — hits on identical program text modulo
   whitespace/comments (``Program.to_text`` canonicalization), misses on a
   changed monoid, mesh topology, storage/rewrite override, or EDB epoch;
   LRU eviction order with counted evictions.

2. **Differential conformance** — a batched k-query fixpoint
   (``run_batched`` / ``FixpointServer.query(force="batched")``) matches k
   sequential single-query runs to <= 1e-8 on the host driver AND the
   on-device ``lax.while_loop`` driver, for personalized PageRank and
   point-to-point reachability.  (The 8-virtual-device mesh half lives in
   ``tests/spmd_serving_program.py``.)

3. **Fail-closed batching** — row-table storage rejects ``run_batched``
   (traced overflow flags cannot cross the vmap boundary) and the
   admission policy routes such programs to sequential dispatch.

4. **Admission policy** — batch-1 and memory-guard requests dispatch
   sequentially, eligible batches vmap, and every decision lands in the
   result's ``serving(...)`` note.
"""

import numpy as np
import pytest

from repro.core.executor import ExecutorError, Relation, compile_program
from repro.core.planner import serving_admission
from repro.core.serving import (
    EDBCache,
    FixpointServer,
    PlanCache,
    POINT_REACHABILITY_TEXT,
    personalized_pagerank_program,
    plan_cache_key,
    point_reachability_program,
    top_k,
)
from repro.launch.query_serve import (
    QueryRequest,
    build_query_server,
    serve_request_loop,
)

N = 24
DAMPING = 0.85


# ---------------------------------------------------------------------------
# Shared fixtures
# ---------------------------------------------------------------------------


def _graph(seed=0, m=70):
    rng = np.random.default_rng(seed)
    pairs = sorted(set(zip(
        rng.integers(0, N, m).tolist(), rng.integers(0, N, m).tolist()
    )))
    pairs = [(a, b) for a, b in pairs if a != b]
    src = np.array([p[0] for p in pairs])
    dst = np.array([p[1] for p in pairs])
    deg = np.bincount(src, minlength=N).astype(np.float32)
    return src, dst, deg


SRC, DST, DEG = _graph()
EDGE = Relation.from_columns(N, SRC, DST)
DEGR = Relation.from_columns(N, np.arange(N), DEG)


def _seed_rel(vertices):
    vs = np.asarray(vertices)
    return Relation.from_columns(
        N, vs, np.full(len(vs), 1.0 / len(vs), np.float32)
    )


def _unary(vertices):
    return Relation.from_columns(N, np.asarray(vertices))


def _server(**kwargs):
    return FixpointServer({"edge": EDGE, "deg": DEGR}, **kwargs)


def _rank_vec(answers):
    rank = answers["rank"]
    return np.where(
        np.asarray(rank.present), np.asarray(rank.values[1]), 0.0
    )


def _ppr_oracle(seed_vertices, iters):
    A = np.zeros((N, N), np.float32)
    A[SRC, DST] = 1.0
    s = np.zeros(N, np.float32)
    s[np.asarray(seed_vertices)] = 1.0 / len(seed_vertices)
    seedmask = s > 0
    r, pres = s.copy(), seedmask.copy()
    for _ in range(iters):
        contrib = np.where(pres, DAMPING * r / np.maximum(DEG, 1.0), 0.0)
        r = A.T @ contrib + np.where(pres & seedmask, (1 - DAMPING) * s, 0.0)
        pres = ((A.T @ pres.astype(np.float32)) > 0) | (pres & seedmask)
    return np.where(pres, r, 0.0)


# ---------------------------------------------------------------------------
# Plan-cache keying
# ---------------------------------------------------------------------------


class TestPlanCacheKey:
    def test_hit_modulo_whitespace_and_comments(self):
        server = _server()
        reformatted = (
            "% a completely different comment\n\n"
            + POINT_REACHABILITY_TEXT.replace(
                "Q2: reach(J+1, Y) :- reach(J, X), edge(X, Y).",
                "Q2:   reach(J+1,   Y)   :-   reach(J, X),  edge(X, Y)."
            )
        )
        k1 = server.plan_key(POINT_REACHABILITY_TEXT, ("src", "dst"))
        k2 = server.plan_key(reformatted, ("src", "dst"))
        assert k1 == k2

    def test_miss_on_changed_rule(self):
        server = _server()
        changed = POINT_REACHABILITY_TEXT.replace(
            "Q2: reach(J+1, Y) :- reach(J, X), edge(X, Y).",
            "Q2: reach(J+1, Y) :- reach(J, X), edge(Y, X)."
        )
        assert server.plan_key(POINT_REACHABILITY_TEXT) \
            != server.plan_key(changed)

    def test_miss_on_changed_monoid(self):
        from repro.core.monoid import get_monoid
        from repro.core.parser import parse

        cc_min = """\
C1: cc(0, X, L)        :- node(X, L).
C2: cc(J+1, X, min<L>) :- cc(J, Y, L), edge(Y, X).
C3: cc(J+1, X, L)      :- cc(J, X, L).
"""
        rels = {"edge": EDGE, "node": DEGR}
        key = {}
        for agg in ("min", "max"):
            prog = parse(
                cc_min.replace("min<L>", f"{agg}<L>"),
                aggregates={agg: get_monoid(agg).as_aggregate()},
            )
            key[agg] = plan_cache_key(prog, rels)
        assert key["min"] != key["max"]

    def test_miss_on_mesh_storage_rewrite_and_epoch(self):
        prog = point_reachability_program()
        rels = {"edge": EDGE}
        base = plan_cache_key(prog, rels)

        class FakeMesh:
            axis_names = ("data",)

            class devices:
                shape = (8,)

        assert plan_cache_key(prog, rels, mesh=FakeMesh()) != base
        assert plan_cache_key(prog, rels, storage="row-table") != base
        assert plan_cache_key(prog, rels, rewrite=True) != base
        assert plan_cache_key(prog, rels, epoch=1) != base
        # None-valued overrides are "not set" — same artifact, same key.
        assert plan_cache_key(prog, rels, storage=None) == base

    def test_lru_eviction_order_and_counters(self):
        cache = PlanCache(capacity=2)
        cache.put("a", "exe_a")
        cache.put("b", "exe_b")
        assert cache.get("a") == "exe_a"      # refreshes a over b
        cache.put("c", "exe_c")               # evicts b (LRU)
        assert cache.keys() == ("a", "c")
        assert "b" not in cache
        assert cache.get("b") is None
        assert cache.counters() == {
            "hits": 1, "misses": 1, "evictions": 1, "size": 2,
        }


# ---------------------------------------------------------------------------
# Differential conformance: batched == sequential
# ---------------------------------------------------------------------------


SEED_SETS = ([0], [3, 5], [7], [1, 9])


class TestBatchedDifferential:
    @pytest.mark.parametrize("on_device", [False, True])
    def test_ppr_batched_matches_sequential(self, on_device):
        server = _server()
        ppr = personalized_pagerank_program(DAMPING)
        batch = [{"seed": _seed_rel(vs)} for vs in SEED_SETS]
        batched = server.query(
            ppr, batch, max_iters=6, on_device=on_device, force="batched"
        )
        seq = server.query(
            ppr, batch, max_iters=6, on_device=on_device,
            force="sequential",
        )
        assert batched.batched and not seq.batched
        for vs, b, s in zip(SEED_SETS, batched.answers, seq.answers):
            got_b, got_s = _rank_vec(b), _rank_vec(s)
            assert np.abs(got_b - got_s).max() <= 1e-8
            assert np.abs(
                got_b - _ppr_oracle(vs, batched.iterations)
            ).max() <= 1e-6

    @pytest.mark.parametrize("on_device", [False, True])
    def test_reachability_batched_matches_sequential(self, on_device):
        server = _server()
        reach = point_reachability_program()
        probes = [
            {"src": _unary([a]), "dst": _unary([b])}
            for a, b in ((0, 9), (3, 3), (11, 2), (5, 20))
        ]
        batched = server.query(
            reach, probes, max_iters=N, on_device=on_device,
            force="batched",
        )
        seq = server.query(
            reach, probes, max_iters=N, on_device=on_device,
            force="sequential",
        )
        for b, s in zip(batched.answers, seq.answers):
            for pred in ("reach", "hit"):
                assert np.array_equal(
                    np.asarray(b[pred].present), np.asarray(s[pred].present)
                )

    def test_run_params_matches_fresh_compile(self):
        reach = point_reachability_program()
        ex = compile_program(
            reach, {"edge": EDGE, "src": _unary([0]), "dst": _unary([1])}
        )
        got = ex.run(
            max_iters=N,
            params={"src": _unary([3]), "dst": _unary([9])},
        ).state
        fresh = compile_program(
            reach, {"edge": EDGE, "src": _unary([3]), "dst": _unary([9])}
        ).run(max_iters=N).state
        for pred in ("reach", "hit"):
            assert np.array_equal(
                np.asarray(got[pred].present),
                np.asarray(fresh[pred].present),
            )


# ---------------------------------------------------------------------------
# Fail-closed batching + parameter validation
# ---------------------------------------------------------------------------


class TestFailClosed:
    def test_row_storage_rejects_run_batched(self):
        reach = point_reachability_program()
        ex = compile_program(
            reach,
            {"edge": EDGE, "src": _unary([0]), "dst": _unary([1])},
            storage="row-table",
        )
        with pytest.raises(ExecutorError, match="row-table"):
            ex.run_batched(
                [{"src": _unary([0]), "dst": _unary([1])}], max_iters=4
            )

    def test_row_storage_server_dispatches_sequentially(self):
        server = _server(storage="row-table")
        reach = point_reachability_program()
        res = server.query(
            reach,
            [{"src": _unary([0]), "dst": _unary([9])},
             {"src": _unary([3]), "dst": _unary([2])}],
            max_iters=8,
        )
        assert not res.batched
        assert "sequential" in res.notes[-1]
        with pytest.raises(ExecutorError, match="cannot force batched"):
            server.query(
                reach,
                [{"src": _unary([0]), "dst": _unary([9])},
                 {"src": _unary([3]), "dst": _unary([2])}],
                max_iters=8, force="batched",
            )

    def test_unknown_and_mismatched_params_rejected(self):
        reach = point_reachability_program()
        ex = compile_program(
            reach, {"edge": EDGE, "src": _unary([0]), "dst": _unary([1])}
        )
        with pytest.raises(ExecutorError, match="not an EDB relation"):
            ex.run(max_iters=4, params={"nope": _unary([0])})
        with pytest.raises(ExecutorError, match="domain"):
            ex.run(max_iters=4, params={
                "src": Relation.from_columns(N * 2, np.array([0]))
            })
        with pytest.raises(ExecutorError, match="same relations"):
            ex.run_batched(
                [{"src": _unary([0])}, {"dst": _unary([1])}], max_iters=4
            )


# ---------------------------------------------------------------------------
# Admission policy
# ---------------------------------------------------------------------------


class TestAdmission:
    def test_single_query_dispatches_sequentially(self):
        server = _server()
        res = server.query(
            point_reachability_program(),
            {"src": _unary([0]), "dst": _unary([9])},
            max_iters=8,
        )
        assert not res.batched
        assert res.decision.reason == "single query"
        assert res.notes[-1].startswith("serving(batch=1: sequential")

    def test_batch_vmaps_and_notes_decision(self):
        server = _server()
        res = server.query(
            point_reachability_program(),
            [{"src": _unary([v]), "dst": _unary([9])} for v in (0, 1, 2)],
            max_iters=8,
        )
        assert res.batched
        assert res.notes[-1].startswith("serving(batch=3: batched")
        # The compiled plan itself stays pristine (shared across requests).
        exe = server.plan_cache.get(res.plan_key)
        assert not any(n.startswith("serving(") for n in exe.plan.notes)

    def test_memory_guard_routes_to_sequential(self):
        exe = compile_program(
            point_reachability_program(),
            {"edge": EDGE, "src": _unary([0]), "dst": _unary([1])},
        )
        decision = serving_admission(
            exe.plan, batch=1024, state_bytes=1 << 24
        )
        assert not decision.batched
        assert "memory guard" in decision.reason
        ok = serving_admission(exe.plan, batch=8, state_bytes=1 << 24)
        assert ok.batched

    def test_batch_below_one_rejected(self):
        exe = compile_program(
            point_reachability_program(),
            {"edge": EDGE, "src": _unary([0]), "dst": _unary([1])},
        )
        with pytest.raises(ValueError, match="batch"):
            serving_admission(exe.plan, batch=0, state_bytes=1024)


# ---------------------------------------------------------------------------
# Caches across requests + invalidation
# ---------------------------------------------------------------------------


class TestServerCaches:
    def test_warm_request_skips_compile(self):
        server = _server()
        ppr = personalized_pagerank_program()
        cold = server.query(ppr, {"seed": _seed_rel([0])}, max_iters=4)
        warm = server.query(ppr, {"seed": _seed_rel([5])}, max_iters=4)
        assert not cold.cache_hit and cold.compile_seconds > 0
        assert warm.cache_hit and warm.compile_seconds == 0.0
        assert warm.plan_key == cold.plan_key
        assert warm.cache["plan_hits"] == 1

    def test_update_relation_bumps_epoch_and_invalidates(self):
        server = _server()
        reach = point_reachability_program()
        params = {"src": _unary([0]), "dst": _unary([9])}
        first = server.query(reach, params, max_iters=8)
        hit_before = int(np.asarray(first.answers[0]["hit"].count()))
        assert hit_before == 1  # 9 reachable from 0 in this graph
        # Remove every edge: same program shape, different answer.
        server.update_relation(
            "edge", Relation.from_columns(N, np.array([], np.int64),
                                          np.array([], np.int64))
        )
        second = server.query(reach, params, max_iters=8)
        assert not second.cache_hit
        assert second.plan_key != first.plan_key
        assert int(np.asarray(second.answers[0]["hit"].count())) == 0

    def test_edb_cache_counts_hits(self):
        cache = EDBCache()
        a = cache.place("edge", EDGE)
        b = cache.place("edge", EDGE)
        assert a is b
        assert cache.counters() == {"hits": 1, "misses": 1, "size": 1}
        cache.invalidate("edge")
        assert cache.counters()["size"] == 0


# ---------------------------------------------------------------------------
# Answer extraction + request loop
# ---------------------------------------------------------------------------


class TestServingFrontDoor:
    def test_top_k_matches_argsort(self):
        server = _server()
        res = server.query(
            personalized_pagerank_program(),
            {"seed": _seed_rel([0, 4])}, max_iters=6,
        )
        ids, scores = top_k(res.answers[0]["rank"], 5)
        ref = _rank_vec(res.answers[0])
        ref = np.where(np.asarray(res.answers[0]["rank"].present),
                       ref, -np.inf)
        np.testing.assert_allclose(
            scores, np.sort(ref)[::-1][:5], rtol=0, atol=0
        )
        assert np.array_equal(ref[ids], scores)

    def test_request_loop_groups_and_preserves_order(self):
        server = build_query_server({"edge": EDGE, "deg": DEGR})
        ppr = personalized_pagerank_program()
        reach = point_reachability_program()
        requests = (
            [QueryRequest(ppr, {"seed": _seed_rel([v])}, max_iters=4,
                          tag=f"ppr{v}") for v in (0, 3, 7)]
            + [QueryRequest(reach,
                            {"src": _unary([0]), "dst": _unary([9])},
                            max_iters=8, tag="probe")]
            + [QueryRequest(ppr, {"seed": _seed_rel([11])}, max_iters=4,
                            tag="late")]
        )
        responses = serve_request_loop(server, requests, max_batch=16)
        assert [r.request.tag for r in responses] \
            == ["ppr0", "ppr3", "ppr7", "probe", "late"]
        assert responses[0].result.batch == 3 and responses[0].batched
        assert responses[3].result.batch == 1
        # Grouped answers match a solo dispatch of the same query.
        solo = server.query(ppr, {"seed": _seed_rel([3])}, max_iters=4,
                            force="sequential")
        assert np.abs(
            _rank_vec(responses[1].answers) - _rank_vec(solo.answers[0])
        ).max() <= 1e-8
