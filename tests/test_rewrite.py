"""Rewrite-rule plan optimizer: units + the stratified-negation guard.

Four properties are defended:

1. **Fail closed at the AntiJoin boundary** — a Select whose columns
   would have to cross into the negated (right) side of an AntiJoin
   raises :class:`RewriteError` instead of silently filtering the
   negation witness set, and the post-pass structural guard re-verifies
   that no AntiJoin right subtree changed.
2. **Pushdown preserves stratified negation** — on the negated-reach
   listing the ``W < 3`` guard sinks into the AntiJoin's *positive*
   side (pinned structurally) and the rewritten fixpoint is
   bit-identical to the unrewritten one.
3. **CSE shares by object identity** — the shared subtree appears as
   one canonical node referenced from multiple rule dataflows, its id
   lands in ``GenericExecutable.shared_ids``, and the executor memo
   returns identical results.
4. **Cost-model units** — cardinality estimates and the greedy
   join order they induce are pinned on hand-made operator trees.
"""

import numpy as np
import pytest

from repro.core.algebra import (
    AntiJoin,
    Join,
    LogicalPlan,
    Project,
    RuleDataflow,
    ScanEDB,
    ScanState,
    Select,
)
from repro.core.datalog import Const
from repro.core.executor import Relation, compile_program
from repro.core.listings import (
    negated_reach_program,
    parsed_negated_reach_program,
    same_generation_program,
    transitive_closure_program,
)
from repro.core.rewrite import (
    RewriteError,
    _negation_right_signatures,
    _pushdown_selects,
    _reorder_joins,
    estimate_cardinality,
    plan_to_dot,
    rewrite_plan,
)


class _FakeRel:
    def __init__(self, n):
        self.n = n

    def count(self):
        return self.n


def _fixture(n=64, seed=0, edges=96):
    rng = np.random.default_rng(seed)
    src, dst = rng.integers(0, n, edges), rng.integers(0, n, edges)
    edge = Relation.from_columns(n, src, dst)
    source = Relation.from_columns(
        n, np.arange(8), np.array([1, 0, 1, 1, 0, 1, 0, 1], np.float32))
    blocked = Relation.from_columns(n, np.array([3, 9, 27]))
    nodew = Relation.from_columns(
        n, np.arange(n), (np.arange(n) % 5).astype(np.float32))
    return {"source": source, "edge": edge, "node": nodew,
            "blocked": blocked}


# ---------------------------------------------------------------------------
# 1. Fail closed at the AntiJoin boundary
# ---------------------------------------------------------------------------


def test_select_crossing_antijoin_boundary_raises():
    # Synthetic mis-planned tree: the Select references 'W', a column that
    # exists only in the negated side.  No translator output looks like
    # this (AntiJoin.schema() == left.schema()), so reaching it means the
    # plan is corrupt — the pass must refuse, not "fix" it.
    aj = AntiJoin(
        ScanEDB("e", ("X", "Y")),
        ScanEDB("b", ("Y", "W")),
        keys=("Y",),
    )
    sel = Select(aj, "<", "W", Const(3))
    with pytest.raises(RewriteError, match="stratified-negation boundary"):
        _pushdown_selects(sel)


def test_guard_signatures_cover_nested_antijoins():
    aj_inner = AntiJoin(ScanEDB("e", ("X", "Y")), ScanEDB("b", ("Y",)),
                        keys=("Y",))
    aj_outer = AntiJoin(aj_inner, ScanEDB("c", ("X",)), keys=("X",))
    df = RuleDataflow("R", "p", Project(("X", "Y"), aj_outer), True)
    sigs = _negation_right_signatures([df])
    assert len(sigs) == 2
    assert sigs[0] == ("X", ("ScanEDB",))
    assert sigs[1] == ("Y", ("ScanEDB",))


# ---------------------------------------------------------------------------
# 2. Pushdown + stratified negation on the negated-reach listing
# ---------------------------------------------------------------------------


def test_negated_reach_pushdown_stays_on_positive_side():
    prog = parsed_negated_reach_program()
    rels = _fixture()
    ex = compile_program(prog, rels, rewrite=True)
    note = [n for n in ex.plan.notes if n.startswith("rewrite(")]
    assert note == ["rewrite(join-reorder: none, pushdown: 1 select, "
                    "cse: 0 shared)"]
    (n2,) = [df for df in ex.logical.body if df.label == "N2"]
    # The W < 3 guard sank below the AntiJoin into its positive side; the
    # negated scan of blocked(Y) is byte-identical.
    assert n2.structure() == (
        "N2", "reach",
        ("Project",
         ("AntiJoin",
          ("Join",
           ("Join", ("ScanState",), ("ScanEDB",)),
           ("Select", ("ScanEDB",))),
          ("ScanEDB",))),
    )

    def find_antijoin(op):
        if isinstance(op, AntiJoin):
            return op
        for c in op.children():
            got = find_antijoin(c)
            if got is not None:
                return got
        return None

    aj = find_antijoin(n2.op)
    assert isinstance(aj.right, ScanEDB) and aj.right.relation == "blocked"

    def has_select(op):
        return isinstance(op, Select) or any(
            has_select(c) for c in op.children())

    assert not has_select(aj.right)


def test_negated_reach_rewrite_matches_unrewritten_fixpoint():
    rels = _fixture()
    res = {}
    for rewrite in (False, True):
        ex = compile_program(negated_reach_program(), rels, rewrite=rewrite)
        res[rewrite] = ex.run(max_iters=80)
    assert res[False].converged and res[True].converged
    a = np.asarray(res[False].state["reach"].present)
    b = np.asarray(res[True].state["reach"].present)
    assert (a == b).all()


# ---------------------------------------------------------------------------
# 3. CSE identity sharing + the executor memo
# ---------------------------------------------------------------------------


def test_cse_shares_subtree_by_identity():
    rels = {"parent": _fixture()["edge"]}
    ex = compile_program(same_generation_program(), rels, rewrite=True)
    assert any(n == "rewrite(join-reorder: none, pushdown: none, "
               "cse: 1 shared)" for n in ex.plan.notes)
    assert ex.shared_ids

    def collect(op, acc):
        acc.append(op)
        for c in op.children():
            collect(c, acc)

    per_rule = {}
    for df in list(ex.logical.init) + list(ex.logical.body):
        acc = []
        collect(df.op, acc)
        per_rule[df.label] = {id(o) for o in acc}
    # At least one canonical shared node is referenced from >= 2 rules.
    shared_hits = [
        sid for sid in ex.shared_ids
        if sum(sid in ids for ids in per_rule.values()) >= 2
    ]
    assert shared_hits, per_rule

    # The memoized engine still computes same-generation correctly.
    plain = compile_program(same_generation_program(), rels)
    a = plain.run(max_iters=80)
    b = ex.run(max_iters=80)
    assert (np.asarray(a.state["sg"].present)
            == np.asarray(b.state["sg"].present)).all()


# ---------------------------------------------------------------------------
# 4. Cost-model units
# ---------------------------------------------------------------------------


def test_estimate_cardinality_units():
    rels = {"edge": _FakeRel(96)}
    edge = ScanEDB("edge", ("X", "Y"))
    state = ScanState("tc", ("X", "Z"))
    assert estimate_cardinality(edge, rels, 64) == 96.0
    assert estimate_cardinality(state, rels, 64) == 64.0**2
    # Unknown EDB falls back to the dense-grid worst case.
    assert estimate_cardinality(ScanEDB("mystery", ("A",)), rels, 64) == 64.0
    join = Join(state, edge, keys=("X",))
    assert estimate_cardinality(join, rels, 64) == 96.0 * 64.0**2 / 64.0
    sel = Select(edge, "<", "Y", Const(3))
    assert estimate_cardinality(sel, rels, 64) == 48.0


def test_reorder_puts_small_edb_scan_first():
    rels = {"edge": _FakeRel(96)}
    state = ScanState("tc", ("J", "X", "Z"))
    edge = ScanEDB("edge", ("Z", "Y"))
    tree = Join(state, edge, keys=("Z",))
    new, fired = _reorder_joins(tree, rels, 64)
    assert fired
    assert isinstance(new.left, ScanEDB) and isinstance(new.right, ScanState)
    # Schema-connected rebuild keeps the natural-join keys.
    assert set(new.keys) == {"Z"}


def test_reorder_never_enters_antijoin_right():
    rels = {"edge": _FakeRel(96)}
    inner = Join(ScanState("p", ("X",)), ScanEDB("edge", ("X", "Y")),
                 keys=("X",))
    aj = AntiJoin(ScanEDB("edge", ("X", "Y")), inner, keys=("X",))
    new, fired = _reorder_joins(aj, rels, 64)
    assert not fired
    assert new.right is inner  # untouched, same object


# ---------------------------------------------------------------------------
# plan_to_dot
# ---------------------------------------------------------------------------


def test_plan_to_dot_renders_rules_and_shares_nodes():
    rels = {"parent": _fixture()["edge"]}
    ex = compile_program(same_generation_program(), rels, rewrite=True)
    dot = plan_to_dot(ex.logical)
    assert dot.startswith("digraph logical_plan {")
    assert dot.rstrip().endswith("}")
    for label in ("S1", "S2", "S3"):
        assert f"rule_{label}" in dot
    # The CSE'd parent(P, X) scan is emitted once but referenced from both
    # S1 and S2: 3 ScanEDB[parent] node declarations for the 4 parent atoms
    # in the program text.
    assert dot.count('label="ScanEDB[parent]') == 3
    assert dot.count('label="ScanEDB[parent](P, X)"') == 1


def test_plan_to_dot_renders_storage_selection():
    ex = compile_program(
        transitive_closure_program(), {"edge": _fixture()["edge"]})
    base = plan_to_dot(ex.logical)
    # Default rendering is byte-identical with or without the argument.
    assert plan_to_dot(ex.logical, storage=None) == base
    assert "box3d" not in base

    dot = plan_to_dot(ex.logical, storage={"tc": "row-table",
                                           "edge": "dense-grid"})
    # tc scans and the tc rule sinks are filled; the dense edge scan is not.
    assert "box3d" in dot and "lightsteelblue" in dot
    for line in dot.splitlines():
        if 'label="ScanEDB[edge]' in line:
            assert "box3d" not in line
        if 'label="Delta[tc]' in line or 'label="ScanState[tc]' in line:
            assert "box3d" in line
    # Attribute-only change: stripping the fills recovers the base render.
    stripped = dot.replace(
        ", shape=box3d, style=filled, fillcolor=lightsteelblue", ""
    ).replace(", style=filled, fillcolor=lightsteelblue", "")
    assert stripped == base


def test_rewrite_plan_requires_no_relations():
    # Estimates degrade to domain**k without materialized relations; the
    # pass still runs and the note is still emitted.
    from repro.core import algebra

    prog = same_generation_program()
    logical = algebra.translate(prog)
    out = rewrite_plan(logical, prog)
    assert len(out.notes) == 1 and out.notes[0].startswith("rewrite(")
