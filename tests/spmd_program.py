"""Multi-device SPMD validation program, run as a subprocess by
test_spmd.py (the XLA device-count flag must be set before jax imports, and
the main test process must keep seeing 1 device)."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys

import numpy as np
import jax
import jax.numpy as jnp


def main() -> None:
    results = {}
    from repro.launch.mesh import make_compat_mesh  # AxisType version shim

    mesh2 = make_compat_mesh((4, 2), ("data", "model"))
    mesh3 = make_compat_mesh((2, 2, 2), ("pod", "data", "model"))

    # --- IMRU: every reduce schedule reaches the same fixpoint -------------
    from repro.core.imru import IMRUTask, compile_imru

    rng = np.random.default_rng(0)
    n, d = 512, 8
    X = rng.normal(size=(n, d)).astype(np.float32)
    w_true = rng.normal(size=(d,)).astype(np.float32)
    y = X @ w_true
    lr = 0.01 / n
    finals = {}
    for sched in ("flat", "hierarchical", "kary_tree", "scatter"):
        task = IMRUTask(
            init_model=lambda: jnp.zeros((d,), jnp.float32),
            map=lambda rec, m: ((rec["x"] @ m - rec["y"]) @ rec["x"]),
            update=lambda j, m, g: m - lr * g,
            tol=1e-7,
        )
        ex = compile_imru(
            task, {"x": jnp.asarray(X), "y": jnp.asarray(y)},
            mesh=mesh3, force_reduce=sched,
        )
        res = ex.run(max_iters=1500)
        finals[sched] = np.asarray(res.state)
    base = finals["flat"]
    results["imru_schedules_agree"] = bool(all(
        np.allclose(base, v, atol=1e-6) for v in finals.values()
    ))
    results["imru_err_vs_true"] = float(np.max(np.abs(base - w_true)))

    # --- int8 error-feedback codec converges too ---------------------------
    from repro.optim.compression import ef_int8_allreduce, init_ef_state
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    Xs = jax.device_put(
        jnp.asarray(X), NamedSharding(mesh2, P(("data",), None)))
    ys = jax.device_put(
        jnp.asarray(y), NamedSharding(mesh2, P(("data",))))

    def step(w, resid):
        def shard_fn(xx, yy, w, r):
            g = (xx @ w - yy) @ xx
            (g_sum,), st = ef_int8_allreduce(
                (g,), init_ef_state((g,))._replace(residuals=(r,)),
                axes=("data",),
            )
            return w - lr * g_sum, st.residuals[0]

        return shard_map(
            shard_fn, mesh=mesh2,
            in_specs=(P(("data",), None), P(("data",)), P(), P()),
            out_specs=(P(), P()),
            check_rep=False,
        )(Xs, ys, w, resid)

    # NOTE: block every step — concurrently in-flight executions that each
    # contain collectives can interleave their device rendezvous on the CPU
    # backend and deadlock (XLA kills the process after 40 s).
    w = jnp.zeros(d, jnp.float32)
    resid = jnp.zeros(d, jnp.float32)
    stepj = jax.jit(step)
    for _ in range(500):
        w, resid = stepj(w, resid)
        jax.block_until_ready(w)
    results["int8_ef_err_vs_true"] = float(np.max(np.abs(
        np.asarray(w) - w_true)))

    # --- Pregel: sharded connectors match the numpy oracle -----------------
    from repro.core.pregel import Graph, VertexProgram, compile_pregel

    N = 64
    rng = np.random.default_rng(1)
    src, dst = [], []
    for v in range(N):
        for _ in range(rng.integers(1, 5)):
            src.append(v)
            dst.append(int(rng.integers(0, N)))
    for v in range(N):
        src.append(int(rng.integers(0, N)))
        dst.append(v)
    src = np.array(src, np.int32)
    dst = np.array(dst, np.int32)
    outdeg = np.bincount(src, minlength=N).astype(np.float32)
    P_ = np.zeros((N, N))
    for s_, d_ in zip(src, dst):
        P_[d_, s_] += 1.0 / outdeg[s_]
    r = np.full(N, 1.0 / N)
    for _ in range(30):
        r = 0.15 / N + 0.85 * P_ @ r

    errs = {}
    for conn in ("dense_psum", "merging", "hash_sort"):
        g = Graph(N, jnp.asarray(src), jnp.asarray(dst),
                  jnp.asarray(outdeg))
        prog = VertexProgram(
            init_vertex=lambda ids, vd: jnp.stack(
                [jnp.full((N,), 1.0 / N), jnp.asarray(outdeg)], axis=1),
            message=lambda j, s, ed: s[:, 0] / jnp.maximum(s[:, 1], 1.0),
            apply=lambda j, s, inbox, got: (
                jnp.stack([0.15 / N + 0.85 * inbox, s[:, 1]], axis=1),
                jnp.ones(s.shape[0], jnp.bool_),
            ),
            combine="sum",
        )
        ex = compile_pregel(prog, g, mesh=mesh2, force_connector=conn)
        res = ex.run(max_iters=30)
        errs[conn] = float(np.max(np.abs(
            np.asarray(res.state[0][:, 0]) - r)))
    results["pregel_errs"] = errs

    # --- LM train step under a real (tiny) mesh ----------------------------
    import dataclasses

    from repro.core.lm_planner import plan_lm
    from repro.core.hardware import MeshSpec
    from repro.launch.train import build_train_step, param_shardings
    from repro.models import lm as lm_mod
    from repro.models.registry import get_config, reduced_config
    from repro.optim import adamw

    cfg = reduced_config(get_config("minitron_8b"))
    spec = MeshSpec((("data", 4), ("model", 2)))
    plan = plan_lm(cfg, "train_4k", spec)
    plan = dataclasses.replace(plan, cfg=cfg, microbatches=2)
    opt = adamw(lr=1e-3)
    step, state_sh, bsh = build_train_step(plan, mesh2, optimizer=opt)
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(0))
    params = jax.device_put(params, state_sh["params"])
    opt_state = jax.device_put(opt.init(params), state_sh["opt"])
    state = {"params": params, "opt": opt_state,
             "step": jax.device_put(jnp.int32(0), state_sh["step"])}
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab, (8, 32)), jnp.int32)
    batch = {"tokens": jax.device_put(toks, bsh({"tokens": toks})["tokens"])}
    losses = []
    for i in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    results["lm_sharded_losses"] = losses
    results["lm_sharded_decreasing"] = bool(losses[-1] < losses[0])

    print("RESULTS_JSON:" + json.dumps(results))


if __name__ == "__main__":
    main()
