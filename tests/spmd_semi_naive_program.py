"""Sharded semi-naive differential-conformance program, run as a subprocess
by test_spmd_semi_naive.py (the XLA device-count flag must be set before jax
imports, and the main test process must keep seeing 1 device).

Property defended: on an 8-virtual-device SPMD mesh, the sharded
delta-frontier (sparse) execution is ``allclose``-identical to the
single-shard dense fixpoint for PageRank (sum), SSSP (min) and connected
components (max) across all three Fig.-9 connectors — per-shard compaction,
the frontier-sized bucket exchanges, the fused got-flag column, and the
collective dense<->sparse mode agreement are execution strategies, never a
semantics change.

Weighted graphs (``Graph.edge_data``) are part of the contract: weighted
SSSP and edge-weighted PageRank run end-to-end on the sharded dense AND
sharded sparse paths (edge-slab partitioning + compacted-index attribute
gather), matching the single-shard dense reference to <= 1e-8 on every
connector — including a mesh with more shards than edges (mostly-padding
weighted slabs).
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import json

import numpy as np
import jax
import jax.numpy as jnp

CONNECTORS = ("dense_psum", "merging", "hash_sort")
N = 64


def _random_graph(seed=1):
    rng = np.random.default_rng(seed)
    src, dst = [], []
    for v in range(N):
        for _ in range(rng.integers(1, 5)):
            src.append(v)
            dst.append(int(rng.integers(0, N)))
    for v in range(N):
        src.append(int(rng.integers(0, N)))
        dst.append(v)
    return np.array(src, np.int32), np.array(dst, np.int32)


def _programs():
    from repro.core.pregel import VertexProgram

    inf = jnp.float32(1e9)
    return {
        # PageRank: sum combine, frontier never collapses (dense throughout).
        "pagerank": (VertexProgram(
            init_vertex=lambda ids, vd: jnp.stack(
                [jnp.full((N,), 1.0 / N), vd], axis=1),
            message=lambda j, s, ed: s[:, 0] / jnp.maximum(s[:, 1], 1.0),
            apply=lambda j, s, inbox, got: (
                jnp.stack([0.15 / N + 0.85 * inbox, s[:, 1]], axis=1),
                jnp.ones(s.shape[0], jnp.bool_)),
            combine="sum",
        ), 15, lambda st: st[:, 0]),
        # SSSP: min combine, collapsing frontier (sparse tail).
        "sssp": (VertexProgram(
            init_vertex=lambda ids, vd: jnp.where(ids == 0, 0.0, inf),
            message=lambda j, s, ed: s + 1.0,
            apply=lambda j, s, inbox, got: (
                jnp.minimum(s, inbox), jnp.minimum(s, inbox) < s),
            combine="min",
        ), 100, lambda st: st),
        # Connected components via max-label propagation: max combine.
        "cc": (VertexProgram(
            init_vertex=lambda ids, vd: ids.astype(jnp.float32),
            message=lambda j, s, ed: s,
            apply=lambda j, s, inbox, got: (
                jnp.maximum(s, inbox), jnp.maximum(s, inbox) > s),
            combine="max",
        ), 100, lambda st: st),
    }


def _weighted_programs():
    """Weighted Listing-1 workloads: the message UDF reads ``edge_data``.

    Weights are exact binary fractions (k * 0.25, k in 1..7) so the min
    combine is bit-exact and the sum combine's reassociation error across
    shard orders stays at the ulp level — the conformance bar is 1e-8.
    """

    from repro.core.pregel import VertexProgram

    inf = jnp.float32(1e9)
    return {
        # Weighted SSSP: relax along per-edge weights, min combine.
        "sssp_w": (VertexProgram(
            init_vertex=lambda ids, vd: jnp.where(ids == 0, 0.0, inf),
            message=lambda j, s, ed: s + ed,
            apply=lambda j, s, inbox, got: (
                jnp.minimum(s, inbox), jnp.minimum(s, inbox) < s),
            combine="min",
        ), 100, lambda st: st),
        # Edge-weighted PageRank: per-edge weight scales the contribution,
        # sum combine, frontier never collapses (dense throughout).
        "pagerank_w": (VertexProgram(
            init_vertex=lambda ids, vd: jnp.stack(
                [jnp.full((N,), 1.0 / N), vd], axis=1),
            message=lambda j, s, ed: s[:, 0] / jnp.maximum(s[:, 1], 1.0) * ed,
            apply=lambda j, s, inbox, got: (
                jnp.stack([0.15 / N + 0.85 * inbox, s[:, 1]], axis=1),
                jnp.ones(s.shape[0], jnp.bool_)),
            combine="sum",
        ), 15, lambda st: st[:, 0]),
    }


def _edge_weights(n_edges: int) -> np.ndarray:
    return (((np.arange(n_edges) % 7) + 1) * 0.25).astype(np.float32)


def main() -> None:
    results = {}
    from repro.launch.mesh import make_data_mesh
    from repro.core.pregel import Graph, VertexProgram, compile_pregel

    mesh = make_data_mesh()
    src, dst = _random_graph()
    outdeg = np.bincount(src, minlength=N).astype(np.float32)
    g = Graph(N, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(outdeg))

    # --- fixpoint conformance: sharded sparse vs single-shard dense --------
    errs = {}
    sparse_engaged = {}
    supports = {}
    for name, (prog, iters, readout) in _programs().items():
        oracle = compile_pregel(prog, g).run(max_iters=iters, on_device=False)
        want = np.asarray(readout(oracle.state[0]))
        for conn in CONNECTORS:
            ex = compile_pregel(prog, g, mesh=mesh, force_connector=conn,
                                semi_naive=True)
            # Pin the dense<->sparse policy so conformance does not depend
            # on the cost model's threshold for this tiny graph.
            ex.plan = dataclasses.replace(
                ex.plan, density_threshold=0.6, sparse_cap_floor=16)
            supports[f"{name}/{conn}"] = bool(ex.supports_sparse)
            res = ex.run(max_iters=iters)
            got = np.asarray(readout(res.state[0]))
            errs[f"{name}/{conn}"] = float(np.max(np.abs(got - want)))
            sparse_engaged[f"{name}/{conn}"] = any(
                m.startswith("sparse@") for m in res.modes)
    results["fixpoint_errs"] = errs
    results["sparse_engaged"] = sparse_engaged
    results["supports_sparse"] = supports

    # --- superstep-level conformance: every connector x combine pair -------
    # One sharded dense superstep vs one sharded frontier-compacted sparse
    # superstep on the same pinned ~10% frontier.
    step_errs = {}
    rng = np.random.default_rng(5)
    active = np.zeros(N, bool)
    active[rng.choice(N, max(1, N // 10), replace=False)] = True
    for op in ("sum", "max", "min"):
        prog = VertexProgram(
            init_vertex=lambda ids, vd: ids.astype(jnp.float32) + 1.0,
            message=lambda j, s, ed: 0.5 * s + 1.0,
            apply=lambda j, s, inbox, got: (
                inbox, jnp.ones(s.shape[0], jnp.bool_)),
            combine=op,
        )
        for conn in CONNECTORS:
            ex = compile_pregel(prog, g, mesh=mesh, force_connector=conn,
                                semi_naive=True)
            ex.plan = dataclasses.replace(ex.plan, sparse_cap_floor=16)
            carry = (ex.init()[0], jnp.asarray(active))
            d_state, d_active = ex.jitted_superstep(carry, jnp.int32(0))
            cap = ex.sparse_cap_for(int(ex.shard_edge_counts(carry[1]).max()))
            s_state, s_active = ex.sparse_superstep(cap)(carry, jnp.int32(0))
            err = float(np.max(np.abs(
                np.asarray(s_state) - np.asarray(d_state))))
            agree = bool(np.array_equal(
                np.asarray(s_active), np.asarray(d_active)))
            step_errs[f"{op}/{conn}"] = err if agree else float("inf")
    results["superstep_errs"] = step_errs

    # --- empty-frontier early termination on the sharded path --------------
    # Path graph: the last active vertex has no out-edges, so the final
    # frontier carries zero active edges — the driver must halt instead of
    # running a no-op sparse superstep.
    src_p = np.arange(N - 1, dtype=np.int32)
    dst_p = np.arange(1, N, dtype=np.int32)
    g_path = Graph(N, jnp.asarray(src_p), jnp.asarray(dst_p),
                   jnp.zeros(N, jnp.float32))
    sssp = _programs()["sssp"][0]
    ex = compile_pregel(sssp, g_path, mesh=mesh, semi_naive=True)
    ex.plan = dataclasses.replace(
        ex.plan, density_threshold=0.6, sparse_cap_floor=4)
    res = ex.run(max_iters=N + 5)
    oracle = compile_pregel(sssp, g_path).run(max_iters=N + 5,
                                              on_device=False)
    results["halt_converged"] = bool(res.converged)
    results["halt_last_mode"] = res.modes[-1] if res.modes else ""
    results["halt_sparse_engaged"] = any(
        m.startswith("sparse@") for m in res.modes)
    results["halt_err"] = float(np.max(np.abs(
        np.asarray(res.state[0]) - np.asarray(oracle.state[0]))))
    # The halt superstep must leave the same all-False active set the dense
    # path produces — no stale frontier flags on any shard.
    results["halt_active_cleared"] = not bool(np.asarray(res.state[1]).any())

    # --- weighted graphs end-to-end: edge-slab partitioning ----------------
    # Weighted SSSP + edge-weighted PageRank on the sharded DENSE path
    # (device fixpoint under shard_map) and the sharded SPARSE path
    # (delta-frontier, compacted-index attribute gather), every connector,
    # vs the single-shard dense oracle — conformance bar 1e-8.
    g_w = Graph(N, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(outdeg),
                edge_data=jnp.asarray(_edge_weights(len(src))))
    w_errs, w_sparse = {}, {}
    for name, (prog, iters, readout) in _weighted_programs().items():
        oracle = compile_pregel(prog, g_w).run(max_iters=iters,
                                               on_device=False)
        want = np.asarray(readout(oracle.state[0]))
        for conn in CONNECTORS:
            dense_sh = compile_pregel(prog, g_w, mesh=mesh,
                                      force_connector=conn)
            r_dense = dense_sh.run(max_iters=iters)
            w_errs[f"{name}/{conn}/dense"] = float(np.max(np.abs(
                np.asarray(readout(r_dense.state[0])) - want)))
            ex = compile_pregel(prog, g_w, mesh=mesh, force_connector=conn,
                                semi_naive=True)
            ex.plan = dataclasses.replace(
                ex.plan, density_threshold=0.6, sparse_cap_floor=16)
            r_sparse = ex.run(max_iters=iters)
            w_errs[f"{name}/{conn}/sparse"] = float(np.max(np.abs(
                np.asarray(readout(r_sparse.state[0])) - want)))
            w_sparse[f"{name}/{conn}"] = any(
                m.startswith("sparse@") for m in r_sparse.modes)
    results["weighted_errs"] = w_errs
    results["weighted_sparse_engaged"] = w_sparse

    # --- weighted superstep conformance: sparse slab gather, every op ------
    # PageRank never leaves the dense mode, so the compacted attribute
    # gather under a sum combine is pinned here: one sharded dense vs one
    # sharded frontier-compacted superstep on the same ~10% frontier, with
    # the message UDF reading the edge weights — for all op x connector.
    w_step_errs = {}
    for op in ("sum", "max", "min"):
        prog = VertexProgram(
            init_vertex=lambda ids, vd: ids.astype(jnp.float32) + 1.0,
            message=lambda j, s, ed: 0.5 * s + ed,
            apply=lambda j, s, inbox, got: (
                inbox, jnp.ones(s.shape[0], jnp.bool_)),
            combine=op,
        )
        for conn in CONNECTORS:
            ex = compile_pregel(prog, g_w, mesh=mesh, force_connector=conn,
                                semi_naive=True)
            ex.plan = dataclasses.replace(ex.plan, sparse_cap_floor=16)
            carry = (ex.init()[0], jnp.asarray(active))
            d_state, d_active = ex.jitted_superstep(carry, jnp.int32(0))
            cap = ex.sparse_cap_for(int(ex.shard_edge_counts(carry[1]).max()))
            s_state, s_active = ex.sparse_superstep(cap)(carry, jnp.int32(0))
            err = float(np.max(np.abs(
                np.asarray(s_state) - np.asarray(d_state))))
            agree = bool(np.array_equal(
                np.asarray(s_active), np.asarray(d_active)))
            w_step_errs[f"{op}/{conn}"] = err if agree else float("inf")
    results["weighted_superstep_errs"] = w_step_errs

    # --- more shards than edges: mostly-padding weighted slabs -------------
    # 3 edges over 8 shards leaves 5 shards with padding-only slabs; the
    # weighted fixpoint must still match the single-shard oracle (regression
    # for the empty-slab index clamp in the compacted gather).
    src_t = np.array([0, 3, 9], np.int32)
    dst_t = np.array([3, 9, 1], np.int32)
    w_t = np.array([0.5, 0.25, 1.0], np.float32)
    g_t = Graph(16, jnp.asarray(src_t), jnp.asarray(dst_t),
                jnp.zeros(16, jnp.float32), edge_data=jnp.asarray(w_t))
    sssp_w = _weighted_programs()["sssp_w"][0]
    oracle_t = compile_pregel(sssp_w, g_t).run(max_iters=20, on_device=False)
    ex_t = compile_pregel(sssp_w, g_t, mesh=mesh, semi_naive=True)
    ex_t.plan = dataclasses.replace(
        ex_t.plan, density_threshold=0.9, sparse_cap_floor=1)
    res_t = ex_t.run(max_iters=20)
    results["tiny_weighted_err"] = float(np.max(np.abs(
        np.asarray(res_t.state[0]) - np.asarray(oracle_t.state[0]))))
    results["tiny_weighted_converged"] = bool(res_t.converged)

    print("RESULTS_JSON:" + json.dumps(results))


if __name__ == "__main__":
    main()
