"""Datalog text frontend: round-trip properties + fail-closed surfaces.

Three properties are defended:

1. **Round-trip** — for seed-generated programs with no anonymous
   variables, ``parse(to_text(p))`` reproduces the exact rule tuple and
   inferred EDB (property-tested; the hypothesis shim replays
   deterministic samples when hypothesis is absent).  Listing programs
   that DO use anonymous/fresh variables round-trip to a textual
   fixpoint instead: ``to_text(parse(to_text(p))) == to_text(p)``.
2. **Equivalence with the hand-built listings** — the ``listings.*_TEXT``
   constants parse to rule-identical programs (TC / CC / SG /
   negated-reach) or algebra-identical plans (pregel / imru / pagerank,
   whose hand-built forms use fresh variables).
3. **Fail closed** — unsafe rules (unbound head/negation/comparison
   variables), unregistered aggregates and UDFs, bad temporal terms, and
   recursion through negation all raise :class:`ParseError` carrying the
   offending source span; nothing unsafe parses into a Program.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, strategies as st
except ImportError:
    from _hypothesis_compat import given, strategies as st  # noqa: F401

from repro.core import algebra
from repro.core.datalog import (
    AggExpr,
    Aggregate,
    Atom,
    Comparison,
    Const,
    Negation,
    Program,
    Rule,
    TempSucc,
    TempVar,
    TempZero,
    Var,
)
from repro.core.listings import (
    connected_components_program,
    imru_program,
    negated_reach_program,
    pagerank_threshold_program,
    parsed_connected_components_program,
    parsed_imru_program,
    parsed_negated_reach_program,
    parsed_pagerank_threshold_program,
    parsed_pregel_program,
    parsed_same_generation_program,
    parsed_transitive_closure_program,
    pregel_program,
    same_generation_program,
    transitive_closure_program,
)
from repro.core.parser import ParseError, parse, to_text


# ---------------------------------------------------------------------------
# 1. Round-trip properties
# ---------------------------------------------------------------------------


def _random_program(seed: int) -> Program:
    """A seed-deterministic XY-stratified program with no anonymous or
    fresh variables, so ``parse(to_text(p))`` must reproduce the rules
    exactly (anonymous variables print as ``_`` and re-parse to *new*
    fresh names, which would break term-level equality)."""

    rng = np.random.default_rng(seed)
    J, Jp1, J0 = TempVar("J"), TempSucc("J"), TempZero()
    X, Y, Z, L = Var("X"), Var("Y"), Var("Z"), Var("L")

    body = [Atom("p", (J, X, Z), temporal=True), Atom("e", (Z, Y))]
    edb = {"e": 2}
    if rng.integers(2):
        body.append(Atom("g", (Y,)))
        edb["g"] = 1
    if rng.integers(2):
        body.append(Negation(Atom("blk", (Y,))))
        edb["blk"] = 1
    if rng.integers(2):
        op = ["<", ">", "<=", ">=", "!=", "=="][int(rng.integers(6))]
        body.append(Comparison(op, Y, Const(int(rng.integers(0, 9)))))

    aggregated = bool(rng.integers(2))
    aggregates = {}
    if aggregated:
        # min-aggregated head over a bound value column.
        body.insert(1, Atom("w", (Y, L)))
        edb["w"] = 2
        head = Atom("p", (Jp1, X, AggExpr("min", L)), temporal=True)
        from repro.core.monoid import get_monoid

        aggregates = {"min": get_monoid("min").as_aggregate()}
    else:
        head = Atom("p", (Jp1, X, Y), temporal=True)

    rules = (
        Rule(Atom("p", (J0, X, Y), temporal=True),
             (Atom("e", (X, Y)),), label="R1"),
        Rule(head, tuple(body), label="R2"),
        Rule(Atom("p", (Jp1, X, Y), temporal=True),
             (Atom("p", (J, X, Y), temporal=True),), label="R3"),
    )
    return Program(rules=rules, edb=edb, aggregates=aggregates, name="prop")


@given(seed=st.integers(min_value=0, max_value=10_000))
def test_roundtrip_random_programs(seed):
    prog = _random_program(seed)
    back = parse(to_text(prog), name=prog.name,
                 aggregates=prog.aggregates)
    assert back.rules == prog.rules
    assert back.edb == prog.edb
    # And the pretty-printer is a fixpoint from the first round on.
    assert to_text(back) == to_text(prog)


def _listing_programs():
    combine = Aggregate("combine", zero=lambda: 0.0,
                        combine=lambda a, b: a + b)
    reduce_ = Aggregate("reduce", zero=lambda: 0.0,
                        combine=lambda a, b: a + b)
    return [
        transitive_closure_program(),
        connected_components_program(),
        same_generation_program(),
        negated_reach_program(),
        pagerank_threshold_program(),
        pregel_program(aggregates={"combine": combine}),
        imru_program(aggregates={"reduce": reduce_}),
    ]


def test_to_text_parse_fixpoint_on_all_listings():
    """Fresh/anonymous variables mean parse(to_text(p)) can't be
    rule-identical for every listing, but the *text* must reach a
    fixpoint after one round trip."""

    for prog in _listing_programs():
        text = to_text(prog)
        back = parse(text, name=prog.name, udfs=prog.udfs,
                     aggregates=prog.aggregates, edb=prog.edb)
        assert to_text(back) == text, prog.name


def test_roundtrip_preserves_rules_when_no_fresh_vars():
    for prog in (transitive_closure_program(),
                 connected_components_program(),
                 same_generation_program(),
                 negated_reach_program()):
        back = parse(to_text(prog), name=prog.name, udfs=prog.udfs,
                     aggregates=prog.aggregates, edb=prog.edb)
        assert back.rules == prog.rules, prog.name


# ---------------------------------------------------------------------------
# 2. Text constants == hand-built listings
# ---------------------------------------------------------------------------


def test_parsed_text_forms_match_hand_built_rules():
    for hand, parsed in (
        (transitive_closure_program(), parsed_transitive_closure_program()),
        (connected_components_program(),
         parsed_connected_components_program()),
        (same_generation_program(), parsed_same_generation_program()),
        (negated_reach_program(), parsed_negated_reach_program()),
    ):
        assert parsed.rules == hand.rules, hand.name
        assert parsed.edb == hand.edb, hand.name
        assert parsed.name == hand.name


def test_parsed_text_forms_match_hand_built_algebra():
    """pregel / imru / pagerank hand-built forms use fresh variables, so
    equivalence is pinned on the translated logical plan instead."""

    combine = Aggregate("combine", zero=lambda: 0.0,
                        combine=lambda a, b: a + b)
    reduce_ = Aggregate("reduce", zero=lambda: 0.0,
                        combine=lambda a, b: a + b)
    for hand, parsed in (
        (pregel_program(aggregates={"combine": combine}),
         parsed_pregel_program(aggregates={"combine": combine})),
        (imru_program(aggregates={"reduce": reduce_}),
         parsed_imru_program(aggregates={"reduce": reduce_})),
        (pagerank_threshold_program(), parsed_pagerank_threshold_program()),
    ):
        assert (algebra.translate(parsed).structure()
                == algebra.translate(hand).structure()), hand.name


def test_parsed_listing_constructors_fail_closed_like_hand_built():
    with pytest.raises(ValueError, match="combine"):
        parsed_pregel_program()
    with pytest.raises(ValueError, match="reduce"):
        parsed_imru_program()


def test_program_to_text_method_delegates():
    prog = transitive_closure_program()
    assert prog.to_text() == to_text(prog)
    assert "T2: tc(J+1, X, Y) :- tc(J, X, Z), edge(Z, Y)." in prog.to_text()


# ---------------------------------------------------------------------------
# 3. Fail-closed surfaces (ParseError + offending span)
# ---------------------------------------------------------------------------


def _err(text, **kw) -> ParseError:
    with pytest.raises(ParseError) as ei:
        parse(text, **kw)
    return ei.value


def test_unbound_head_variable_has_span():
    err = _err("R1: p(0, X, Y) :- e(X).")
    assert "head variable 'Y'" in str(err)
    assert err.span is not None
    assert (err.span.line, err.span.col) == (1, 13)  # points at Y
    assert "R1: p(0, X, Y) :- e(X)." in str(err)  # source line rendered
    assert "^" in str(err)  # caret


def test_unbound_negation_variable_has_span():
    err = _err("R1: p(0, X) :- e(X), !q(Y).\nR2: p(J+1, X) :- p(J, X).")
    assert "appears only under negation" in str(err)
    assert (err.span.line, err.span.col) == (1, 25)


def test_unbound_comparison_variable_has_span():
    err = _err("R1: p(0, X) :- e(X), Y > 1.\nR2: p(J+1, X) :- p(J, X).")
    assert "comparison over unbound variable 'Y'" in str(err)
    assert (err.span.line, err.span.col) == (1, 22)


def test_anonymous_variable_rejected_in_head():
    err = _err("R1: p(0, X, _) :- e(X).")
    assert "anonymous variable" in str(err)
    assert (err.span.line, err.span.col) == (1, 13)


def test_unregistered_aggregate_names_registry():
    err = _err(
        "C1: cc(0, X, L) :- node(X, L).\n"
        "C2: cc(J+1, X, frob<L>) :- cc(J, Y, L), edge(Y, X).\n"
        "C3: cc(J+1, X, L) :- cc(J, X, L).\n"
    )
    assert "unregistered aggregate 'frob'" in str(err)
    assert "CombineMonoid registry" in str(err)
    assert err.span.line == 2


def test_registered_monoids_resolve_without_explicit_aggregates():
    prog = parse(
        "C1: cc(0, X, L) :- node(X, L).\n"
        "C2: cc(J+1, X, min<L>) :- cc(J, Y, L), edge(Y, X).\n"
        "C3: cc(J+1, X, L) :- cc(J, X, L).\n",
        name="cc",
    )
    assert "min" in prog.aggregates
    assert prog.aggregates["min"].idempotent


def test_unregistered_udf_has_span():
    err = _err("R1: p(0, X, Y) :- e(X), f(X -> Y).\n"
               "R2: p(J+1, X, Y) :- p(J, X, Y).")
    assert "unregistered UDF 'f'" in str(err)
    assert (err.span.line, err.span.col) == (1, 25)


def test_bad_temporal_successor_rejected():
    err = _err("R1: p(0, X) :- e(X).\nR2: p(J+2, X) :- p(J, X).")
    assert "J+1" in str(err)
    assert err.span.line == 2


def test_temporal_predicate_never_derived():
    err = _err("R1: p(0, X) :- q(J, X).")
    assert "never derived" in str(err)


def test_syntax_error_points_at_offending_token():
    err = _err("R1: p(0 X) :- e(X).")
    assert "expected ')'" in str(err)
    assert (err.span.line, err.span.col) == (1, 9)


def test_recursion_through_negation_fails_closed():
    # Non-temporal mutual recursion through negation: there is no
    # XY-schedule for this program and parse() must refuse it.
    err = _err("B1: p(X) :- e(X), !q(X).\nB3: q(X) :- e(X), !p(X).")
    assert "not XY-stratified" in str(err)
    assert "recursive predicate" in str(err)
    # The span names the offending rule, not just the program.
    assert err.span.line == 1
    assert "B1:" in str(err)


def test_temporal_negation_of_sibling_stratum_fails_closed():
    err = _err(
        "A1: p(0, X) :- e(X).\n"
        "A2: p(J+1, X) :- p(J, X), !q(X).\n"
        "A3: q(X) :- p(J, X), marked(X).\n"
    )
    assert "not XY-stratified" in str(err)
    assert err.span.line == 2  # A2, the rule with the offending negation


def test_temporal_mutual_negation_at_prior_state_is_legal():
    # Negating the *current* state of a sibling temporal predicate is
    # XY-legal (both advance in lockstep) — the frontend must not
    # over-reject.
    prog = parse(
        "A1: p(0, X) :- e(X).\n"
        "A2: p(J+1, X) :- p(J, X), !q(J, X).\n"
        "A3: q(0, X) :- e(X).\n"
        "A4: q(J+1, X) :- q(J, X), !p(J, X).\n",
        name="temporal-neg",
    )
    assert {r.label for r in prog.rules} == {"A1", "A2", "A3", "A4"}


def test_comments_strings_and_annotations_parse():
    prog = parse(
        "% leading comment\n"
        "R1: p(0, X, 'it\\'s') :- e(X).  % trailing\n"
        "@frontier F1: q(X) :- p(J, X, S).\n"
        "F2: @frontier r(X) :- p(J, X, S).\n",
        name="syntax",
    )
    labels = {r.label: r for r in prog.rules}
    assert labels["R1"].head.args[2] == Const("it's")
    assert labels["F1"].frontier and labels["F2"].frontier


def test_empty_program_rejected():
    err = _err("% nothing but comments\n")
    assert "empty program" in str(err)
