"""Explicit-exchange + out-of-core streaming conformance.

Two layers:

* An 8-virtual-device subprocess (tests/spmd_exchange_program.py, shared
  _spmd_subprocess runner) proving every shipped workload lands on the
  same answer under all three exchange lowerings — implicit ``gspmd``,
  the key-hash ``bucket-a2a`` connector, and ``psum-scatter`` — and that
  the explicit connectors actually planned (``exchange(...)`` notes).
* In-process (1 device) differentials proving chunked streaming is
  chunk-count-invariant ({1, 2, 7} — including a count that does not
  divide the slab), survives crash-mid-chunk + checkpoint restore, and
  fails closed on the batched/on-device dispatch paths.
"""

import numpy as np
import pytest

from tests._spmd_subprocess import run_spmd_program

EXCHANGE_TAGS = (
    "tc/gspmd", "tc/bucket-a2a",
    "tc-chunked/bucket-a2a",
    "cc-semi/bucket-a2a",
    "negated-reach/bucket-a2a",
    "pipeline/gspmd", "pipeline/bucket-a2a", "pipeline/psum-scatter",
)


@pytest.fixture(scope="module")
def results():
    return run_spmd_program("spmd_exchange_program.py")


@pytest.mark.parametrize("tag", EXCHANGE_TAGS)
def test_exchange_mode_matches_single_shard_dense(results, tag):
    assert tag in results["errs"], sorted(results["errs"])
    assert results["errs"][tag] <= 1e-8, (tag, results["errs"][tag])
    assert results["fallbacks"][tag] is False, \
        f"{tag} fell back to dense storage on the mesh"


def test_explicit_connectors_are_planned(results):
    notes = results["notes"]
    assert any(n.startswith("exchange(") and "bucket-a2a[cap=" in n
               for n in notes["tc/bucket-a2a"]), notes["tc/bucket-a2a"]
    assert any("psum-scatter" in n
               for n in notes["pipeline/psum-scatter"]), \
        notes["pipeline/psum-scatter"]
    assert any(n.startswith("chunking(edge: 3 chunks")
               for n in notes["tc-chunked/bucket-a2a"]), \
        notes["tc-chunked/bucket-a2a"]
    # gspmd override pins every site to the implicit partitioner
    gspmd = [n for n in notes["tc/gspmd"] if n.startswith("exchange(")]
    assert gspmd and all(n.endswith(": gspmd)") for n in gspmd), \
        notes["tc/gspmd"]


# --------------------------------------------------------------------------
# In-process chunked streaming differentials (single device).
# --------------------------------------------------------------------------

N = 64


def _grid(rel):
    from repro.core.executor import RowRelation

    if isinstance(rel, RowRelation):
        rel = rel.to_dense()
    return (np.asarray(rel.present),
            {k: np.asarray(v) for k, v in rel.values.items()})


def _max_err(a, b, preds):
    err = 0.0
    for p in preds:
        ap, av = _grid(a.state[p])
        bp, bv = _grid(b.state[p])
        err = max(err, float(np.sum(ap != bp)))
        for k in av:
            err = max(err, float(
                np.abs(np.where(ap, av[k] - bv[k], 0.0)).max()))
    return err


def _tc_setup():
    from repro.core.executor import Relation
    from repro.core.listings import transitive_closure_program

    rng = np.random.default_rng(7)
    edge = Relation.from_columns(
        N, rng.integers(0, N, 96), rng.integers(0, N, 96))
    return transitive_closure_program(), {"edge": edge}


@pytest.mark.parametrize("m", (1, 2, 7))
def test_chunked_tc_matches_unchunked_exactly(m):
    from repro.core.executor import compile_program

    program, rels = _tc_setup()
    base = compile_program(program, dict(rels), storage="row-table")
    chunked = compile_program(
        program, dict(rels), storage="row-table", chunks={"edge": m})
    if m > 1:
        assert f"chunking(edge: {m} chunks" in "".join(chunked.plan.notes)
        assert set(chunked.chunked_edb) == {"edge"}
        assert len(chunked.chunked_edb["edge"]) == m
    a = base.run(max_iters=64)
    b = chunked.run(max_iters=64)
    assert not a.storage_fallback and not b.storage_fallback
    assert _max_err(a, b, ("tc",)) == 0.0


@pytest.mark.parametrize("m", (2, 7))
def test_chunked_pipeline_matches_unchunked(m):
    from repro.core.executor import Relation, compile_program
    from repro.core.listings import pagerank_threshold_program

    rng = np.random.default_rng(3)
    n = 256
    psrc = np.repeat(np.arange(n), 3)
    pdst = rng.integers(0, n, 3 * n)
    deg = np.bincount(psrc, minlength=n).astype(np.float32)
    rels = {
        "edge": Relation.from_columns(n, psrc, pdst),
        "node": Relation.from_columns(
            n, np.arange(n), np.full(n, 1.0 / n, np.float32), deg,
            np.full(n, 0.15 / n, np.float32)),
    }
    program = pagerank_threshold_program(tau=1.5 / n)
    base = compile_program(
        program, dict(rels), storage="row-table", semi_naive=True
    ).run(max_iters=60)
    chunked = compile_program(
        program, dict(rels), storage="row-table", semi_naive=True,
        chunks={"edge": m},
    ).run(max_iters=60)
    assert not base.storage_fallback and not chunked.storage_fallback
    assert _max_err(base, chunked, ("rank", "hot", "reach")) <= 1e-8


def test_auto_chunking_from_hbm_budget():
    """A budget smaller than the EDB slab splits the scan automatically and
    the streamed fixpoint still matches the in-memory one exactly."""

    from repro.core.executor import compile_program

    program, rels = _tc_setup()
    base = compile_program(program, dict(rels), storage="row-table")
    auto = compile_program(
        program, dict(rels), storage="row-table", hbm_budget=256)
    assert len(auto.chunked_edb.get("edge", [])) > 1
    assert any(n.startswith("chunking(edge:") and "budget=256B" in n
               for n in auto.plan.notes), auto.plan.notes
    assert _max_err(base.run(max_iters=64), auto.run(max_iters=64),
                    ("tc",)) == 0.0


def test_chunked_crash_mid_chunk_restores_and_converges(tmp_path):
    """Satellite (d): a crash part-way through the chunk stream — some
    chunk partials already accumulated — discards the partial step and the
    driver restores from the last checkpoint; the replayed run must land on
    the uninterrupted answer exactly."""

    from repro.core.executor import compile_program
    from repro.ft.elastic import FailureInjector

    program, rels = _tc_setup()
    clean = compile_program(
        program, dict(rels), storage="row-table", chunks={"edge": 3}
    ).run(max_iters=64)
    inj = FailureInjector(chunk_crashes=((3, 1), (6, 2)))
    faulted = compile_program(
        program, dict(rels), storage="row-table", chunks={"edge": 3}
    ).run(
        max_iters=64,
        checkpoint_dir=str(tmp_path / "ckpt"),
        checkpoint_every=2,
        injector=inj,
    )
    assert faulted.restarts == 2
    fired = [e for e in inj.fired if e.kind == "crash"]
    assert [e.detail for e in fired] == ["chunk 1", "chunk 2"]
    assert _max_err(clean, faulted, ("tc",)) == 0.0


def test_chunked_fails_closed_on_device_and_batched():
    from repro.core.executor import ExecutorError, compile_program

    program, rels = _tc_setup()
    ex = compile_program(
        program, dict(rels), storage="row-table", chunks={"edge": 2})
    with pytest.raises(ExecutorError, match="host"):
        ex.run(max_iters=4, on_device=True)
    with pytest.raises(ExecutorError, match="chunk"):
        ex.run_batched([{}], max_iters=4)
    with pytest.raises(ExecutorError, match="chunked"):
        ex.phase_step_fn()
