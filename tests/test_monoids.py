"""Generalized aggregate algebra: registry fail-closed behavior + single-
shard differential conformance for the four shipped monoids.

Three properties are defended:

1. **Fail closed at registration** — a combine that is not associative /
   commutative / identity-absorbing (or falsely claims idempotence) raises
   :class:`MonoidError` from ``register_monoid`` (property-tested; the
   hypothesis shim replays deterministic samples when hypothesis is
   absent).
2. **Fail closed at the semi-naive rewrite** — ``delta_rewritable_rules``
   rejects rules whose aggregate is registered but not delta-safe.
3. **Conformance** — argmin / topk / mean / logsumexp fixpoints and
   supersteps match independent NumPy oracles on the single-shard dense
   AND sparse (delta-frontier) paths; the sharded mirror lives in
   ``tests/test_spmd_monoids.py``.
"""

import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import (  # noqa: F401
        HealthCheck, given, settings, strategies as st,
    )

import jax.numpy as jnp

from repro.core.monoid import (
    CombineMonoid,
    MonoidError,
    check_monoid,
    generic_segment_combine,
    get_monoid,
    register_monoid,
    registered_monoids,
)
from repro.core import stratify
from repro.core.physical import (
    dense_psum_exchange,
    fused_got_exchange,
    scatter_combine,
    segment_combine_sorted,
)
from repro.core.pregel import Graph, VertexProgram, compile_pregel

from _monoid_workloads import (
    build_workloads,
    finite,
    make_graph,
    np_combines,
    np_identity,
    numpy_pregel,
)

N = 48


# ---------------------------------------------------------------------------
# Registry basics
# ---------------------------------------------------------------------------


def test_builtins_registered_and_lawful():
    names = registered_monoids()
    for required in ("sum", "max", "min", "argmin", "topk", "mean",
                     "logsumexp"):
        assert required in names
    for name in names:
        check_monoid(get_monoid(name))  # raises on violation


def test_unknown_monoid_fails_with_registered_list():
    with pytest.raises(MonoidError, match="registered:"):
        get_monoid("median")


def test_duplicate_registration_rejected():
    with pytest.raises(MonoidError, match="already registered"):
        register_monoid(CombineMonoid(
            "sum", combine=jnp.add, identity=0.0))


def test_metadata_flags():
    assert get_monoid("argmin").idempotent
    assert get_monoid("argmin").is_delta_safe
    for name in ("topk", "mean", "logsumexp"):
        m = get_monoid(name)
        assert not m.idempotent
        assert not m.is_delta_safe, name
    assert get_monoid("mean").kernel_op == "sum"   # rides the fast path
    assert get_monoid("topk").kernel_op is None    # generic XLA path


# ---------------------------------------------------------------------------
# Fail-closed registration (property-tested)
# ---------------------------------------------------------------------------

# A family of broken combines, each violating exactly one law the checker
# must catch.  (a+b)/2 breaks associativity; a+b with identity 1 breaks the
# identity law; a-b breaks commutativity; sum claiming idempotence breaks
# the idempotence check.
_BROKEN = {
    "non_associative": dict(
        combine=lambda a, b: (a + b) / 2, identity=0.0),
    "identity_violating": dict(combine=jnp.add, identity=1.0),
    "non_commutative": dict(
        combine=lambda a, b: a - b, identity=0.0),
    "false_idempotence": dict(
        combine=jnp.add, identity=0.0, idempotent=True),
}


@settings(deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    kind=st.sampled_from(sorted(_BROKEN)),
    width=st.integers(min_value=1, max_value=4),
)
def test_broken_monoids_fail_closed_at_registration(kind, width):
    spec = dict(_BROKEN[kind])
    spec.setdefault("idempotent", False)
    m = CombineMonoid(
        name=f"_broken_{kind}_{width}", width=width,
        min_width=1, **spec,
    )
    with pytest.raises(MonoidError):
        register_monoid(m)
    assert m.name not in registered_monoids()


@settings(deadline=None)
@given(width=st.integers(min_value=1, max_value=6))
def test_lawful_custom_monoid_registers_and_unregisters(width):
    # A lawful monoid at any width registers cleanly (max is associative,
    # commutative, idempotent, -inf-absorbing); overwrite=True keeps the
    # replayed property examples independent.
    m = CombineMonoid(
        "_lawful_probe", combine=jnp.maximum, identity=float("-inf"),
        width=width, idempotent=True,
    )
    register_monoid(m, overwrite=True)
    assert get_monoid("_lawful_probe").width == width


def test_bad_kernel_op_rejected():
    with pytest.raises(MonoidError, match="kernel_op"):
        register_monoid(CombineMonoid(
            "_bad_kernel", combine=jnp.add, identity=0.0,
            kernel_op="prod"))


# ---------------------------------------------------------------------------
# Fail-closed semi-naive eligibility
# ---------------------------------------------------------------------------


def _program_with_combine(name):
    from repro.core.listings import pregel_program

    return pregel_program(
        udfs={"init_vertex": lambda i, d: i, "update": lambda *a: a[:2]},
        aggregates={
            "combine": get_monoid(name).as_aggregate(recomputable=False)
        },
    )


def test_delta_rules_reject_non_delta_safe_registered_aggregate():
    # topk / mean / logsumexp are registered but NOT delta-safe: without
    # the Pregel executor's recomputable-inbox guarantee, L3 must keep its
    # full (naive) read.
    for name in ("topk", "mean", "logsumexp"):
        eligible = stratify.delta_rewritable_rules(
            _program_with_combine(name))
        assert "L3" not in eligible, name


def test_delta_rules_accept_idempotent_monoid_aggregate():
    # argmin is idempotent (lex-min absorbs re-delivery) — delta-safe even
    # without the recomputable-inbox guarantee.
    assert "L3" in stratify.delta_rewritable_rules(
        _program_with_combine("argmin"))


def test_pregel_front_end_marks_inboxes_recomputable():
    # Inside the Pregel plan every inbox is rebuilt per superstep, so even
    # non-idempotent monoids license the semi-naive rewrite there.
    for name in ("topk", "mean", "logsumexp", "argmin"):
        prog = VertexProgram(
            init_vertex=lambda i, d: i, message=lambda j, s, e: s,
            apply=lambda j, s, i, g: (i, jnp.ones(1, jnp.bool_)),
            combine=name,
        )
        assert "L3" in stratify.delta_rewritable_rules(prog.program()), name


# ---------------------------------------------------------------------------
# Combine-primitive conformance vs NumPy (segment + scatter + exchanges)
# ---------------------------------------------------------------------------


def _np_segment_oracle(name, vals, ids, n_seg, active=None):
    comb = np_combines()[name]
    out = [None] * n_seg
    for e in range(len(ids)):
        if active is not None and not active[e]:
            continue
        i = int(ids[e])
        if not (0 <= i < n_seg):
            continue
        row = vals[e].astype(np.float64)
        out[i] = row if out[i] is None else comb(out[i], row)
    width = vals.shape[1]
    ident = np_identity(name, width)
    return np.stack([ident if r is None else r for r in out])


@pytest.mark.parametrize("name,width", [
    ("argmin", 2), ("argmin", 3), ("topk", 4), ("mean", 2),
    ("logsumexp", 1), ("logsumexp", 3),
])
@pytest.mark.parametrize("masked", [False, True])
def test_segment_and_scatter_combine_match_numpy(name, width, masked):
    rng = np.random.default_rng(17)
    e, n_seg = 96, 13
    vals = (rng.standard_normal((e, width)) * 2).astype(np.float32)
    if name == "topk":
        vals = np.sort(vals, axis=1)[:, ::-1].copy()  # in-domain rows
    ids = np.sort(rng.integers(0, n_seg, e)).astype(np.int32)
    active = rng.random(e) > 0.3 if masked else None
    ref = _np_segment_oracle(name, vals, ids, n_seg, active)
    m = get_monoid(name)
    ident = m.identity_slab((n_seg, width), jnp.float32)

    sorted_out = segment_combine_sorted(
        jnp.asarray(vals), jnp.asarray(ids), n_seg, name,
        edge_active=None if active is None else jnp.asarray(active),
    )
    np.testing.assert_allclose(
        finite(sorted_out), finite(ref), rtol=1e-5, atol=1e-6)

    perm = rng.permutation(e)
    scat_out = scatter_combine(
        jnp.asarray(vals[perm]), jnp.asarray(ids[perm]), n_seg, name,
        edge_active=None if active is None else jnp.asarray(active[perm]),
    )
    np.testing.assert_allclose(
        finite(scat_out), finite(ref), rtol=1e-5, atol=1e-6)

    # Empty segments read the identity row on the generic path.
    empty = ~np.isin(np.arange(n_seg), ids[active] if masked else ids)
    if empty.any() and m.kernel_op is None:
        np.testing.assert_array_equal(
            finite(np.asarray(sorted_out)[empty]),
            finite(np.asarray(ident)[empty]))


def test_kernels_public_wrapper_routes_generic_monoids():
    from repro.kernels.segment_combine.ops import kernel_eligible, \
        segment_combine

    vals = jnp.asarray(np.random.default_rng(0).standard_normal(
        (16, 2)).astype(np.float32))
    vals = jnp.sort(vals, axis=1)[:, ::-1]
    ids = jnp.asarray(np.sort(np.random.default_rng(1).integers(0, 5, 16))
                      .astype(np.int32))
    # Generic monoids never take the Pallas kernel, even in interpret mode.
    assert not kernel_eligible(vals, True, "topk")
    assert not kernel_eligible(vals, True, "argmin")
    assert kernel_eligible(vals, True, "mean")  # rides the sum fast path
    out = segment_combine(vals, ids, 5, "topk")
    ref = _np_segment_oracle("topk", np.asarray(vals), np.asarray(ids), 5)
    np.testing.assert_allclose(finite(out), finite(ref), rtol=1e-5)


@pytest.mark.parametrize("name,width", [
    ("argmin", 2), ("topk", 3), ("mean", 2), ("logsumexp", 2),
])
def test_fused_got_exchange_generic_monoids(name, width):
    rng = np.random.default_rng(23)
    e, n = 64, 12
    pay = (rng.standard_normal((e, width)) * 2).astype(np.float32)
    if name == "topk":
        pay = np.sort(pay, axis=1)[:, ::-1].copy()
    dst = rng.integers(0, n, e).astype(np.int32)
    valid = rng.random(e) > 0.4

    ex = lambda fused: dense_psum_exchange(
        jnp.asarray(dst), fused, n, (), name,
        edge_mask=jnp.asarray(valid), flag_cols=1)
    inbox, got = fused_got_exchange(
        ex, jnp.asarray(pay), jnp.asarray(valid), name)
    ref = _np_segment_oracle(name, pay, dst, n, active=valid)
    got_ref = np.zeros(n, bool)
    for i in range(e):
        if valid[i]:
            got_ref[dst[i]] = True
    np.testing.assert_array_equal(np.asarray(got), got_ref)
    np.testing.assert_allclose(
        finite(np.asarray(inbox)[got_ref]), finite(ref[got_ref]),
        rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Payload validation at compile
# ---------------------------------------------------------------------------


def test_structured_monoid_rejects_scalar_payload_at_compile():
    src, dst, _ = make_graph(16)
    g = Graph(16, jnp.asarray(src), jnp.asarray(dst),
              jnp.zeros(16, jnp.float32))
    prog = VertexProgram(
        init_vertex=lambda ids, vd: ids.astype(jnp.float32),
        message=lambda j, s, ed: s,            # [E] — argmin needs [E, >=2]
        apply=lambda j, s, i, got: (i, jnp.ones(s.shape[0], jnp.bool_)),
        combine="argmin",
    )
    with pytest.raises(MonoidError, match="width"):
        compile_pregel(prog, g)


def test_mean_rejects_wrong_width_at_compile():
    src, dst, _ = make_graph(16)
    g = Graph(16, jnp.asarray(src), jnp.asarray(dst),
              jnp.zeros(16, jnp.float32))
    prog = VertexProgram(
        init_vertex=lambda ids, vd: ids.astype(jnp.float32),
        message=lambda j, s, ed: jnp.stack([s, s, s], axis=1),  # width 3
        apply=lambda j, s, i, got: (s, jnp.ones(s.shape[0], jnp.bool_)),
        combine="mean",
    )
    with pytest.raises(MonoidError, match="width"):
        compile_pregel(prog, g)


def test_planner_records_monoid_payload_terms():
    src, dst, w = make_graph(24)
    g = Graph(24, jnp.asarray(src), jnp.asarray(dst),
              jnp.zeros(24, jnp.float32),
              edge_data=jnp.asarray(w.astype(np.float32)))
    wl = build_workloads(24)["argmin_sssp"]
    ex = compile_pregel(wl["prog"], g)
    assert "combine-monoid(argmin, 8B/msg, xla-generic)" in ex.plan.notes
    assert ex.plan.mesh is not None
    # mean rides the sum fast path and says so.
    g2 = Graph(24, jnp.asarray(src), jnp.asarray(dst),
               jnp.zeros(24, jnp.float32))
    ex2 = compile_pregel(build_workloads(24)["mean_labelprop"]["prog"], g2)
    assert "combine-monoid(mean, 8B/msg, sum-fast-path)" in ex2.plan.notes


# ---------------------------------------------------------------------------
# Single-shard fixpoint + superstep conformance vs the NumPy oracles
# ---------------------------------------------------------------------------


def _graph_for(wl, n):
    src, dst, w = make_graph(n)
    edata = (jnp.asarray(w.astype(np.float32)) if wl["weighted"] else None)
    return (
        Graph(n, jnp.asarray(src), jnp.asarray(dst),
              jnp.zeros(n, jnp.float32), edge_data=edata),
        src, dst, (w if wl["weighted"] else None),
    )


@pytest.mark.parametrize("name", sorted(build_workloads(8)))
@pytest.mark.parametrize("connector", ["dense_psum", "merging", "hash_sort"])
def test_single_shard_fixpoints_match_numpy_oracle(name, connector):
    wl = build_workloads(N)[name]
    g, src, dst, w = _graph_for(wl, N)
    ref, _, _ = numpy_pregel(
        src, dst, w, N, wl["np_state0"], wl["np_msg"],
        np_combines()[wl["combine"]], wl["np_apply"], wl["np_finalize"],
        wl["iters"],
    )
    ex = compile_pregel(wl["prog"], g, force_connector=connector)
    res = ex.run(max_iters=wl["iters"], on_device=False)
    np.testing.assert_allclose(
        finite(res.state[0]), finite(ref), rtol=1e-5, atol=1e-6,
        err_msg=f"{name}/{connector}/dense")

    # Delta-frontier (sparse) execution with the policy pinned on: the
    # adaptive driver must produce the same fixpoint.
    ex_sn = compile_pregel(wl["prog"], g, force_connector=connector,
                           semi_naive=True)
    ex_sn.plan = dataclasses.replace(
        ex_sn.plan, density_threshold=0.6, sparse_cap_floor=16)
    res_sn = ex_sn.run(max_iters=wl["iters"])
    np.testing.assert_allclose(
        finite(res_sn.state[0]), finite(ref), rtol=1e-5, atol=1e-6,
        err_msg=f"{name}/{connector}/sparse")


def test_collapsing_monoid_workloads_engage_sparse_path():
    for name in ("argmin_sssp", "topk_prop"):
        wl = build_workloads(N)[name]
        g, *_ = _graph_for(wl, N)
        ex = compile_pregel(wl["prog"], g, semi_naive=True)
        ex.plan = dataclasses.replace(
            ex.plan, density_threshold=0.6, sparse_cap_floor=16)
        res = ex.run(max_iters=wl["iters"])
        assert res.converged, name
        assert any(m.startswith("sparse@") for m in res.modes), name


def test_mean_finalize_reaches_apply():
    # The apply UDF must see sum/count already divided: a mean inbox of a
    # constant-label graph is that constant, so one superstep keeps every
    # label exactly (0.5 * c + 0.5 * c == c).
    wl = build_workloads(N)["mean_labelprop"]
    src, dst, _ = make_graph(N)
    g = Graph(N, jnp.asarray(src), jnp.asarray(dst),
              jnp.zeros(N, jnp.float32))
    prog = dataclasses.replace(
        wl["prog"],
        init_vertex=lambda ids, vd: jnp.full((N,), 2.5, jnp.float32))
    ex = compile_pregel(prog, g)
    state, active = ex.jitted_superstep(ex.init(), jnp.int32(0))
    np.testing.assert_allclose(np.asarray(state), 2.5, rtol=1e-6)


def test_generic_segment_combine_zero_rows():
    m = get_monoid("argmin")
    out = generic_segment_combine(
        jnp.zeros((0, 2), jnp.float32), jnp.zeros((0,), jnp.int32), 4, m)
    assert out.shape == (4, 2)
    np.testing.assert_array_equal(
        finite(out), finite(np.asarray(m.identity_slab((4, 2),
                                                       jnp.float32))))
