"""Tests for Datalog stratification + XY-stratification (paper Appendix B)."""

import pytest

from repro.core.datalog import (
    Aggregate,
    AggExpr,
    Atom,
    Comparison,
    Const,
    Negation,
    Program,
    Rule,
    TempSucc,
    TempVar,
    TempZero,
    Var,
)
from repro.core import stratify
from repro.core.listings import imru_program, pregel_program


def _sum_agg():
    return Aggregate("reduce", zero=lambda: 0.0, combine=lambda a, b: a + b)


def _combine_agg():
    return Aggregate("combine", zero=lambda: 0.0, combine=lambda a, b: a + b)


def make_imru():
    return imru_program(aggregates={"reduce": _sum_agg()})


def make_pregel():
    return pregel_program(aggregates={"combine": _combine_agg()})


# ---------------------------------------------------------------------------
# Ordinary stratification
# ---------------------------------------------------------------------------


def test_nonrecursive_program_stratifies():
    p = Program(
        rules=(
            Rule(Atom("b", (Var("X"),)), (Atom("a", (Var("X"),)),), label="r1"),
            Rule(
                Atom("c", (Var("X"),)),
                (Atom("b", (Var("X"),)), Negation(Atom("a", (Var("X"),)))),
                label="r2",
            ),
        ),
        edb={"a": 1},
    )
    strata = stratify.stratify(p)
    assert strata["c"] > strata["a"]


def test_negative_cycle_rejected():
    p = Program(
        rules=(
            Rule(Atom("p", (Var("X"),)), (Negation(Atom("q", (Var("X"),))), Atom("e", (Var("X"),))), label="r1"),
            Rule(Atom("q", (Var("X"),)), (Negation(Atom("p", (Var("X"),))), Atom("e", (Var("X"),))), label="r2"),
        ),
        edb={"e": 1},
    )
    with pytest.raises(stratify.StratificationError):
        stratify.stratify(p)


def test_transitive_closure_is_recursive():
    X, Y, Z = Var("X"), Var("Y"), Var("Z")
    p = Program(
        rules=(
            Rule(Atom("tc", (X, Y)), (Atom("edge", (X, Y)),), label="base"),
            Rule(
                Atom("tc", (X, Z)),
                (Atom("tc", (X, Y)), Atom("edge", (Y, Z))),
                label="step",
            ),
        ),
        edb={"edge": 2},
    )
    assert "tc" in stratify.recursive_predicates(p)
    # Positive recursion stratifies fine.
    stratify.stratify(p)


# ---------------------------------------------------------------------------
# Theorem 1: the two listings are XY-stratified
# ---------------------------------------------------------------------------


def test_imru_is_xy_stratified():
    classes = stratify.xy_validate(make_imru())
    assert classes == {"G1": "base", "G2": "x", "G3": "y"}


def test_pregel_is_xy_stratified():
    classes = stratify.xy_validate(make_pregel())
    assert classes["L1"] == "base"
    assert classes["L2"] == "base"
    assert classes["L3"] == "x"
    assert classes["L4"] == "frontier"
    assert classes["L5"] == "frontier"
    assert classes["L6"] == "x"
    assert classes["L7"] == "y"
    assert classes["L8"] == "y"


def test_imru_residual_two_strata():
    """Theorem 2: the new_/old_ residual of Listing 2 is stratified with
    new_collect in the highest stratum."""

    residual = stratify.xy_transform(make_imru())
    strata = stratify.stratify(residual)
    assert strata["new_collect"] == max(
        strata["new_collect"], strata["new_model"]
    )
    assert strata["new_collect"] > strata["new_model"]


def test_pregel_residual_stratified():
    """Theorem 3: the residual of Listing 1 stratifies (two strata)."""

    residual = stratify.xy_transform(make_pregel())
    strata = stratify.stratify(residual)
    assert strata["new_collect"] > strata["new_send"]
    assert strata["new_maxVertexJ"] > strata["new_vertex"]
    assert strata["new_superstep"] >= strata["new_collect"]
    assert max(strata.values()) - min(strata.values()) >= 1


def test_imru_schedule_order():
    sched = stratify.iteration_schedule(make_imru())
    assert [r.label for r in sched.init_rules] == ["G1"]
    assert [r.label for r in sched.body_rules] == ["G2", "G3"]
    assert "model" in sched.carried


def test_pregel_schedule_order():
    """Section 3.3: 'each iteration fires rules in the order L3, ..., L8'."""

    sched = stratify.iteration_schedule(make_pregel())
    assert [r.label for r in sched.init_rules] == ["L1", "L2"]
    assert [r.label for r in sched.body_rules] == [
        "L3", "L4", "L5", "L6", "L7", "L8",
    ]
    assert set(sched.carried) >= {"vertex", "send"}


# ---------------------------------------------------------------------------
# Negative cases: programs violating Definition 2 are rejected
# ---------------------------------------------------------------------------


def test_missing_temporal_argument_rejected():
    J, Jp1 = TempVar("J"), TempSucc("J")
    X = Var("X")
    p = Program(
        rules=(
            Rule(Atom("p", (TempZero(), X), temporal=True), (Atom("e", (X,)),), label="init"),
            # q is in the recursive cycle but has no temporal argument.
            Rule(Atom("q", (X,)), (Atom("p", (J, X), temporal=True),), label="bad"),
            Rule(
                Atom("p", (Jp1, X), temporal=True),
                (Atom("q", (X,)), Atom("p", (J, X), temporal=True)),
                label="step",
            ),
        ),
        edb={"e": 1},
    )
    with pytest.raises(stratify.XYError):
        stratify.xy_validate(p)


def test_y_rule_without_current_goal_rejected():
    Jp1 = TempSucc("J")
    X = Var("X")
    p = Program(
        rules=(
            Rule(Atom("p", (TempZero(), X), temporal=True), (Atom("e", (X,)),), label="init"),
            # Y-rule whose only recursive goal is at J+1: no positive goal at J.
            Rule(
                Atom("p", (Jp1, X), temporal=True),
                (Atom("p", (Jp1, X), temporal=True),),
                label="bad",
            ),
        ),
        edb={"e": 1},
    )
    with pytest.raises(stratify.XYError):
        stratify.xy_validate(p)


def test_x_rule_reading_future_rejected():
    J, Jp1 = TempVar("J"), TempSucc("J")
    X = Var("X")
    p = Program(
        rules=(
            Rule(Atom("p", (TempZero(), X), temporal=True), (Atom("e", (X,)),), label="init"),
            Rule(
                Atom("q", (J, X), temporal=True),
                (Atom("p", (Jp1, X), temporal=True),),
                label="bad-x",
            ),
            Rule(
                Atom("p", (Jp1, X), temporal=True),
                (Atom("q", (J, X), temporal=True), Atom("p", (J, X), temporal=True)),
                label="step",
            ),
        ),
        edb={"e": 1},
    )
    with pytest.raises(stratify.XYError):
        stratify.xy_validate(p)


def test_program_validate_checks_arity_and_udfs():
    X = Var("X")
    p = Program(
        rules=(
            Rule(Atom("p", (X,)), (Atom("e", (X, X)),), label="r"),
        ),
        edb={"e": 1},  # declared arity 1, used with arity 2
    )
    with pytest.raises(ValueError):
        p.validate()
