"""Serving driver: batched prefill + decode with a slot-based scheduler
(continuous-batching-lite) — the serving analogue of the paper's fixpoint:
carried state = (KV cache, position) per slot, superstep = one decode step.

    PYTHONPATH=src python examples/serve_lm.py --requests 8 --gen 32
"""

import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hardware import MeshSpec
from repro.core.lm_planner import plan_lm
from repro.launch.serve import build_decode_step, build_prefill_step, \
    greedy_sample
from repro.models import lm
from repro.models.common import ArchConfig

CFG = ArchConfig(
    name="repro-serve-25m", family="dense", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=2, d_ff=1024, vocab=4096, head_dim=64,
    window=None, param_dtype="float32", compute_dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = CFG
    B = args.requests
    cache_len = args.prompt_len + args.gen

    plan = plan_lm(cfg, "decode_32k", MeshSpec((("data", 1),)))
    plan = dataclasses.replace(plan, cfg=cfg)
    prefill_fn, _ = build_prefill_step(plan, None, cache_len)
    decode_fn, _, _ = build_decode_step(plan, None)

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (B, args.prompt_len)), jnp.int32)

    t0 = time.perf_counter()
    logits, cache, pos = prefill_fn(params, {"tokens": prompts})
    logits = jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {B} x {args.prompt_len} tokens in {t_prefill:.3f}s "
          f"({B * args.prompt_len / t_prefill:.0f} tok/s)")

    token = greedy_sample(logits)
    out = [token]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode_fn(params, cache, token,
                                  jnp.int32(args.prompt_len + i))
        token = greedy_sample(logits)
        out.append(token)
    jax.block_until_ready(token)
    t_decode = time.perf_counter() - t0
    total = B * (args.gen - 1)
    print(f"decode: {total} tokens in {t_decode:.3f}s "
          f"({total / t_decode:.0f} tok/s, "
          f"{t_decode / (args.gen - 1) * 1e3:.1f} ms/step)")
    gen = jnp.concatenate(out, axis=1)
    print("sample output ids (req 0):", np.asarray(gen[0])[:16].tolist())


if __name__ == "__main__":
    main()
