"""End-to-end LM training driver: the paper's IMRU dataflow as an LM
trainer, with checkpoint/restart fault tolerance.

    # ~100M-param model, a few hundred steps (CPU: ~10-20s/step)
    PYTHONPATH=src python examples/train_lm.py --steps 200

    # quick smoke (seconds)
    PYTHONPATH=src python examples/train_lm.py --preset smoke --steps 20

Training *is* the Iterative Map-Reduce-Update program: map = per-microbatch
grad, reduce = gradient sum (planner-scheduled collectives at pod scale),
update = AdamW.  The host fixpoint driver adds checkpointing and
restart-on-failure (--crash-at N injects a failure to demonstrate).
"""

import argparse
import dataclasses
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore, latest_step
from repro.core.hardware import MeshSpec
from repro.core.lm_planner import plan_lm
from repro.data import DataConfig, batch_for_step
from repro.launch.train import build_train_step
from repro.models import lm
from repro.models.common import ArchConfig
from repro.optim import adamw, warmup_cosine

PRESETS = {
    # ~103M params: 12 x 768 transformer, GQA 12/4, vocab 16k
    "100m": ArchConfig(
        name="repro-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=16000, head_dim=64,
        param_dtype="float32", compute_dtype="float32",
    ),
    "smoke": ArchConfig(
        name="repro-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=1024, head_dim=32,
        param_dtype="float32", compute_dtype="float32",
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=tuple(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None,
                    help="default: artifacts/train_lm_ckpt_<preset>")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="inject a failure at this step (FT demo)")
    ap.add_argument("--task", default="copy", choices=("copy", "zipf"),
                    help="copy: induction-head task (needs long training); "
                         "zipf: unigram structure, loss drops within tens "
                         "of steps")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    if args.ckpt_dir is None:
        args.ckpt_dir = f"artifacts/train_lm_ckpt_{args.preset}"
    n_params = sum(
        int(np.prod(l.shape))
        for l in jax.tree_util.tree_leaves(lm.abstract_params(cfg))
    )
    print(f"model: {cfg.name} ({n_params / 1e6:.1f}M params)")

    plan = plan_lm(cfg, "train_4k", MeshSpec((("data", 1),)))
    plan = dataclasses.replace(plan, cfg=cfg, microbatches=1)
    opt = adamw(lr=warmup_cosine(args.lr, 20, args.steps))
    step_fn, _, _ = build_train_step(plan, mesh=None, optimizer=opt)

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch, task=args.task)
    store = CheckpointStore(args.ckpt_dir, keep=2)

    # restart-from-checkpoint: exact resume of model + opt + data cursor
    start = latest_step(args.ckpt_dir)
    if start is not None:
        like = {
            "params": lm.init_params(cfg, jax.random.PRNGKey(0)),
            "opt": opt.init(lm.init_params(cfg, jax.random.PRNGKey(0))),
            "step": jnp.int32(0),
        }
        state, start, extra = store.restore(like)
        print(f"resumed from checkpoint at step {start}")
    else:
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.int32(0)}
        start = 0

    t_start = time.perf_counter()
    for i in range(start, args.steps):
        if i == args.crash_at:
            print(f"!! injected crash at step {i} — rerun to resume")
            raise SystemExit(17)
        batch = batch_for_step(dc, i)   # pure f(seed, step): exact replay
        state, metrics = step_fn(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            dt = time.perf_counter() - t_start
            tok_s = (i - start + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({tok_s:.0f} tok/s)")
        if args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            store.save(i + 1, state, extra={"data_step": i + 1})
    store.wait()
    print("done.")


if __name__ == "__main__":
    main()
