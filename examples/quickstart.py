"""Quickstart: the paper's Batch Gradient Descent task through the full
declarative stack (paper §5.1 at laptop scale).

    PYTHONPATH=src python examples/quickstart.py

You write the three Iterative Map-Reduce-Update UDFs; the framework turns
them into the Listing-2 Datalog program, proves XY-stratification, derives
the Figure-2 logical plan, cost-plans the physical dataflow, and runs the
fixpoint.
"""

import numpy as np
import jax.numpy as jnp

from repro.core.imru import IMRUTask, compile_imru


def main() -> None:
    rng = np.random.default_rng(0)
    n, d = 4096, 32
    X = rng.normal(size=(n, d)).astype(np.float32)
    w_true = rng.normal(size=(d,)).astype(np.float32)
    y = X @ w_true + 0.01 * rng.normal(size=n).astype(np.float32)
    lr = 0.05 / n

    task = IMRUTask(
        # init_model: G1's init_model UDF
        init_model=lambda: jnp.zeros((d,), jnp.float32),
        # map: per-record (gradient) statistic, vectorized + pre-aggregated
        map=lambda rec, m: ((rec["x"] @ m - rec["y"]) @ rec["x"]),
        # update: G3's model refinement; converged when model stops moving
        update=lambda j, m, g: m - lr * g,
        tol=1e-6,
    )

    ex = compile_imru(task, {"x": jnp.asarray(X), "y": jnp.asarray(y)})
    print("== Datalog program (Listing 2) ==")
    print(ex.program.pretty())
    print("\n== logical plan (Figure 2) ==")
    print(ex.logical.pretty())
    print("\n== physical plan ==")
    print(ex.plan.explain())

    res = ex.run(max_iters=2000)
    err = float(jnp.max(jnp.abs(res.state - w_true)))
    print(f"\nconverged={res.converged} after {res.iterations} iterations "
          f"({res.seconds:.2f}s); max |w - w*| = {err:.2e}")
    assert err < 0.05


if __name__ == "__main__":
    main()
