"""Quickstart: the paper's Batch Gradient Descent task through the full
declarative stack (paper §5.1 at laptop scale), plus an arbitrary recursive
query on the same engine.

    PYTHONPATH=src python examples/quickstart.py

Part 1 — you write the three Iterative Map-Reduce-Update UDFs; the framework
turns them into the Listing-2 Datalog program, proves XY-stratification,
derives the Figure-2 logical plan, cost-plans the physical dataflow, and
runs the fixpoint.

Part 2 — the unified executor runs programs NO front-end hardcodes: a
transitive closure written as *Datalog text*, parsed by ``core.parser``,
optimized by the ``core.rewrite`` pass (join reordering, select pushdown,
CSE — see the ``rewrite(...)`` plan note), and compiled by
``compile_program`` onto the same engine.
"""

import numpy as np
import jax.numpy as jnp

from repro.core.executor import Relation, compile_program
from repro.core.imru import IMRUTask, compile_imru
from repro.core.parser import parse

TC_TEXT = """
% Transitive closure, straight from text to the unified engine.
T1: tc(0, X, Y)   :- edge(X, Y).
T2: tc(J+1, X, Y) :- tc(J, X, Z), edge(Z, Y).
T3: tc(J+1, X, Y) :- tc(J, X, Y).
"""


def transitive_closure_demo() -> None:
    """ANY XY-stratified program on the unified executor (no front-end),
    written as Datalog text."""

    n = 64
    rng = np.random.default_rng(3)
    src = rng.integers(0, n, 2 * n)
    dst = rng.integers(0, n, 2 * n)

    program = parse(TC_TEXT, name="transitive-closure")
    ex = compile_program(
        program,
        {"edge": Relation.from_columns(n, src, dst)},
        rewrite=True,
    )
    print("\n== generic program (transitive closure, parsed from text) ==")
    print(ex.program.pretty())
    print("\n== generic physical plan ==")
    print(ex.plan.explain())
    rewrite_notes = [x for x in ex.plan.notes if x.startswith("rewrite(")]
    assert rewrite_notes, ex.plan.notes
    print(f"\nrewrite pass: {rewrite_notes[0]}")

    res = ex.run(max_iters=2 * n)
    tc = np.asarray(res.state["tc"].present)

    # Independent NumPy oracle: boolean-matrix closure.
    adj = np.zeros((n, n), bool)
    adj[src, dst] = True
    want = adj.copy()
    while True:
        new = want | (want @ adj)
        if (new == want).all():
            break
        want = new
    assert (tc == want).all()
    print(f"\nconverged={res.converged} after {res.iterations} iterations; "
          f"|tc| = {tc.sum()} facts (matches the NumPy closure)")


def main() -> None:
    rng = np.random.default_rng(0)
    n, d = 4096, 32
    X = rng.normal(size=(n, d)).astype(np.float32)
    w_true = rng.normal(size=(d,)).astype(np.float32)
    y = X @ w_true + 0.01 * rng.normal(size=n).astype(np.float32)
    lr = 0.05 / n

    task = IMRUTask(
        # init_model: G1's init_model UDF
        init_model=lambda: jnp.zeros((d,), jnp.float32),
        # map: per-record (gradient) statistic, vectorized + pre-aggregated
        map=lambda rec, m: ((rec["x"] @ m - rec["y"]) @ rec["x"]),
        # update: G3's model refinement; converged when model stops moving
        update=lambda j, m, g: m - lr * g,
        tol=1e-6,
    )

    ex = compile_imru(task, {"x": jnp.asarray(X), "y": jnp.asarray(y)})
    print("== Datalog program (Listing 2) ==")
    print(ex.program.pretty())
    print("\n== logical plan (Figure 2) ==")
    print(ex.logical.pretty())
    print("\n== physical plan ==")
    print(ex.plan.explain())

    res = ex.run(max_iters=2000)
    err = float(jnp.max(jnp.abs(res.state - w_true)))
    print(f"\nconverged={res.converged} after {res.iterations} iterations "
          f"({res.seconds:.2f}s); max |w - w*| = {err:.2e}")
    assert err < 0.05

    transitive_closure_demo()


if __name__ == "__main__":
    main()
