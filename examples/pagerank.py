"""PageRank via the Pregel front-end (paper §5.2 at laptop scale), with the
planner choosing the message-exchange connector (Fig. 4 / Fig. 9).

    PYTHONPATH=src python examples/pagerank.py [--connector dense_psum]
                                               [--semi-naive]

``--semi-naive`` compiles the delta-frontier plan and runs the adaptive
dense<->sparse driver (PR 1); the per-superstep mode choices recorded in
``FixpointResult.modes`` are printed after the run.  PageRank keeps every
vertex active, so the expected readout is all-dense — the point is seeing
the adaptive policy's decisions, not a speedup on this workload.
"""

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core.pregel import Graph, VertexProgram, compile_pregel


def synthetic_webgraph(n: int, seed: int = 0):
    """Power-law-ish out-degrees, preferential-attachment-ish targets."""

    rng = np.random.default_rng(seed)
    out_deg = np.clip(rng.zipf(2.1, n), 1, 100)
    src = np.repeat(np.arange(n, dtype=np.int32), out_deg)
    dst = (rng.integers(0, n, src.shape[0]) * rng.integers(
        1, 3, src.shape[0]) % n).astype(np.int32)
    return src, dst


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--vertices", type=int, default=1 << 14)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--connector", default=None,
                    choices=(None, "dense_psum", "merging", "hash_sort"))
    ap.add_argument("--semi-naive", action="store_true", dest="semi_naive",
                    help="delta-frontier plan + adaptive dense<->sparse "
                         "driver; prints the per-superstep modes")
    args = ap.parse_args()

    N = args.vertices
    src, dst = synthetic_webgraph(N)
    outdeg = np.bincount(src, minlength=N).astype(np.float32)
    print(f"graph: {N} vertices, {len(src)} edges")

    prog = VertexProgram(
        init_vertex=lambda ids, vd: jnp.stack(
            [jnp.full((N,), 1.0 / N), jnp.asarray(outdeg)], axis=1),
        message=lambda j, s, ed: s[:, 0] / jnp.maximum(s[:, 1], 1.0),
        apply=lambda j, s, inbox, got: (
            jnp.stack([0.15 / N + 0.85 * inbox, s[:, 1]], axis=1),
            jnp.ones(s.shape[0], jnp.bool_)),
        combine="sum",
    )
    g = Graph(N, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(outdeg))
    ex = compile_pregel(prog, g, force_connector=args.connector,
                        semi_naive=args.semi_naive)
    print("\n== physical plan ==")
    print(ex.plan.explain())

    t0 = time.perf_counter()
    res = ex.run(max_iters=args.iters)
    dt = time.perf_counter() - t0
    ranks = np.asarray(res.state[0][:, 0])
    top = np.argsort(-ranks)[:10]
    print(f"\n{res.iterations} supersteps in {dt:.2f}s "
          f"({len(src) * res.iterations / dt:.2e} edge-updates/s)")
    if args.semi_naive:
        counts = {m: res.modes.count(m) for m in dict.fromkeys(res.modes)}
        print("adaptive modes:", list(res.modes))
        print("mode counts:", counts)
    print("top-10:", list(zip(top.tolist(), np.round(ranks[top], 6))))


if __name__ == "__main__":
    main()
