"""Online fixpoint serving: compile once, answer many queries.

    PYTHONPATH=src python examples/serve_queries.py

A :class:`repro.core.serving.FixpointServer` holds the shared EDB (the
graph) and a plan cache keyed by program shape.  The first personalized-
PageRank request pays ``compile_program`` + the first jit trace; every
later request — including requests with DIFFERENT seed vertices — reuses
the cached executable and only swaps the parameter grids.  Batches of
parameterized queries are vmapped through ONE fixpoint when the
planner-costed admission policy says batching wins (see the
``serving(...)`` note on each result).

The demo asserts its answers against an independent NumPy PPR oracle and
shows the request-loop front door (``repro.launch.query_serve``)
coalescing mixed PPR/reachability traffic.  docs/serving.md walks through
the same session.
"""

import time

import numpy as np

from repro.core.executor import Relation
from repro.core.serving import (
    FixpointServer,
    personalized_pagerank_program,
    point_reachability_program,
    top_k,
)
from repro.launch.query_serve import (
    QueryRequest,
    build_query_server,
    serve_request_loop,
)

N = 256
DEG = 4
DAMPING = 0.85
ITERS = 10


def build_graph(n=N, deg=DEG, seed=11):
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, n * deg)
    keep = src != dst
    pairs = sorted(set(zip(src[keep].tolist(), dst[keep].tolist())))
    src = np.array([p[0] for p in pairs])
    dst = np.array([p[1] for p in pairs])
    degree = np.bincount(src, minlength=n).astype(np.float32)
    return src, dst, degree


def seed_rel(vertices, n=N):
    vs = np.asarray(vertices)
    return Relation.from_columns(
        n, vs, np.full(len(vs), 1.0 / len(vs), np.float32))


def unary(vertices, n=N):
    return Relation.from_columns(n, np.asarray(vertices))


def ppr_oracle(src, dst, degree, seeds, iters, n=N, d=DAMPING):
    """Independent NumPy oracle for the served PPR program."""
    adj = np.zeros((n, n), np.float32)
    adj[src, dst] = 1.0
    seed = np.zeros(n, np.float32)
    seed[np.asarray(seeds)] = 1.0 / len(seeds)
    mask = seed > 0
    rank, pres = seed.copy(), mask.copy()
    for _ in range(iters):
        push = adj.T @ np.where(pres, d * rank / np.maximum(degree, 1.0), 0.0)
        pres_new = (adj.T @ pres.astype(np.float32)) > 0
        pres = pres_new | (pres & mask)
        rank = push + (1 - d) * seed * (pres & mask)
    return np.where(pres, rank, 0.0)


def rank_vec(answers):
    rel = answers["rank"]
    return np.where(np.asarray(rel.present),
                    np.asarray(rel.values[1]), 0.0)


def main() -> None:
    src, dst, degree = build_graph()
    relations = {
        "edge": Relation.from_columns(N, src, dst),
        "deg": Relation.from_columns(N, np.arange(N), degree),
    }
    server = FixpointServer(relations)
    ppr = personalized_pagerank_program(DAMPING)

    # -- request 1: plan-cache miss (compile + first trace) ----------------
    t0 = time.perf_counter()
    cold = server.query(ppr, {"seed": seed_rel([0, 1])}, max_iters=ITERS)
    cold_ms = (time.perf_counter() - t0) * 1e3
    print(f"cold request:   {cold_ms:8.1f} ms "
          f"(compile {cold.compile_seconds * 1e3:.1f} ms, "
          f"cache_hit={cold.cache_hit})")
    assert not cold.cache_hit and cold.compile_seconds > 0

    # -- request 2: different seeds, same program shape -> cache hit -------
    t0 = time.perf_counter()
    warm = server.query(ppr, {"seed": seed_rel([7])}, max_iters=ITERS)
    warm_ms = (time.perf_counter() - t0) * 1e3
    print(f"warm request:   {warm_ms:8.1f} ms "
          f"(compile {warm.compile_seconds * 1e3:.1f} ms, "
          f"cache_hit={warm.cache_hit})")
    assert warm.cache_hit and warm.compile_seconds == 0.0
    assert warm.plan_key == cold.plan_key

    # -- a batch of 8 queries through ONE vmapped fixpoint -----------------
    rng = np.random.default_rng(5)
    seed_sets = [rng.choice(N, 2, replace=False).tolist() for _ in range(8)]
    batch = [{"seed": seed_rel(vs)} for vs in seed_sets]
    t0 = time.perf_counter()
    res = server.query(ppr, batch, max_iters=ITERS)
    batch_ms = (time.perf_counter() - t0) * 1e3
    print(f"batch of 8:     {batch_ms:8.1f} ms "
          f"({batch_ms / 8:.1f} ms/query, batched={res.batched})")
    print(f"admission note: {res.notes[-1]}")
    for vs, ans in zip(seed_sets, res.answers):
        want = ppr_oracle(src, dst, degree, vs, ITERS)
        err = float(np.abs(rank_vec(ans) - want).max())
        assert err <= 1e-6, (vs, err)
    print("all 8 batched answers match the NumPy PPR oracle (<= 1e-6)")

    ids, scores = top_k(res.answers[0]["rank"], 5)
    print(f"top-5 for seeds {seed_sets[0]}: "
          + ", ".join(f"v{i}={s:.4f}" for i, s in zip(ids, scores)))

    # -- mixed traffic through the request loop ----------------------------
    qserver = build_query_server(relations)
    reach = point_reachability_program()
    requests = [
        QueryRequest(ppr, {"seed": seed_rel(vs)}, max_iters=ITERS,
                     tag=f"ppr{j}")
        for j, vs in enumerate(seed_sets[:3])
    ] + [
        QueryRequest(reach, {"src": unary([0]), "dst": unary([9])},
                     max_iters=N, tag="probe"),
    ]
    responses = serve_request_loop(qserver, requests)
    hits = np.flatnonzero(np.asarray(responses[-1].answers["hit"].present))
    print(f"request loop:   {len(responses)} responses "
          f"({sum(r.batched for r in responses)} served from a vmapped "
          f"batch); reach(0 -> 9) = {bool(len(hits))}")
    counters = qserver.plan_cache.counters()
    print(f"plan cache:     {counters}")


if __name__ == "__main__":
    main()
